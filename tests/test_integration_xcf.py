"""Integration: partitioner → XCF → runtime; elastic remesh restore; full-DP
rules."""

import jax
import numpy as np

from repro.core.partitioner import best_point, explore
from repro.core.profiler import profile_device, profile_host
from repro.core.xcf import XCF, make_xcf
from repro.runtime.scheduler import runtime_from_xcf

from helpers import make_topfilter, topfilter_expected


def test_xcf_roundtrip_into_runtime(tmp_path):
    """The paper's full loop: profile -> solve -> emit XCF -> load XCF -> run."""
    g, _ = make_topfilter(n=2000, vectorized=True)
    prof, _ = profile_host(g)
    prof = profile_device(g, prof, block=512)
    pts = explore(g, prof, thread_counts=(1, 2), accel_options=(False, True))
    bp = best_point(pts)
    p = tmp_path / "best.json"
    bp.xcf.save(p)

    g2, got = make_topfilter(n=2000, vectorized=True)
    rt = runtime_from_xcf(g2, XCF.load(p), block=512)
    rt.run_threads()
    assert got == topfilter_expected(n=2000)


def test_xcf_fifo_depths_applied():
    g, got = make_topfilter(n=500, vectorized=True)
    xcf = make_xcf(g.name, {"source": "t0", "filter": "t1", "sink": "t0"})
    from repro.core.xcf import ConnectionSpec

    xcf.connections.append(ConnectionSpec("source", "OUT", "filter", "IN", 8))
    rt = runtime_from_xcf(g, xcf)
    assert rt.fifos["source.OUT->filter.IN"].capacity == 8
    rt.run_threads()
    assert got == topfilter_expected(n=500)


def test_elastic_remesh_restore(tmp_path):
    """Checkpoints are mesh-agnostic: save under one rule set, restore under
    different sharding rules (the surviving-pods scenario)."""
    from repro.checkpoint import restore, save
    from repro.configs import get_config
    from repro.distributed.sharding import full_dp_rules

    from repro.launch.mesh import make_test_mesh
    from repro.model import lm

    cfg = get_config("smollm-135m").reduced()
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    save(tmp_path, 3, params)

    mesh = make_test_mesh()
    rules2 = full_dp_rules(cfg, mesh)  # a *different* placement policy
    from repro.distributed.sharding import defs_shardings
    from repro.model.lm import model_defs

    sh = defs_shardings(model_defs(cfg), mesh, rules2)
    restored, _ = restore(tmp_path, 3, params, shardings=sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_full_dp_rules_structure():
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.distributed.sharding import full_dp_rules, make_pspec

    from helpers import abstract_mesh

    mesh = abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("mamba2-130m")
    rules = full_dp_rules(cfg, mesh)
    # batch shards over both axes; nothing else touches the model axis
    assert make_pspec(("batch",), (256,), mesh, rules) == P(("data", "model"))
    assert make_pspec(("tp",), (1536,), mesh, rules) == P(None)
    assert make_pspec(("seq",), (4096,), mesh, rules) == P(None)
