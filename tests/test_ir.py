"""Middle-end: typed IR, pass pipeline, legalization, dead elimination,
depth inference, SDF detection/fusion plumbing, ir_dump."""

import pytest

import repro
from repro.core.actor import Action, Actor, Port, simple_actor
from repro.core.graph import ActorGraph, GraphError
from repro.core.xcf import ConnectionSpec, make_xcf
from repro.ir import IRModule, legalize_xcf, lower
from repro.ir.passes import device_dtype_ok

from helpers import make_chain, make_topfilter


# ---------------------------------------------------------------------------
# Lowering basics
# ---------------------------------------------------------------------------


def test_lower_host_default():
    g, _ = make_topfilter(n=16)
    mod = lower(g)
    assert isinstance(mod, IRModule)
    assert set(mod.actors) == {"source", "filter", "sink"}
    assert [r.kind for r in mod.regions.values()] == ["sw"]
    assert mod.assignment() == {a: "t0" for a in g.actors}
    # rates: filter has two actions with different produces -> dynamic
    assert not mod.actors["filter"].rate.static
    assert mod.actors["filter"].rate.consume_rate("IN") == 1
    # sink/source host-only survives lowering
    assert not mod.actors["sink"].device_ok


def test_lower_records_pass_trace():
    g, _ = make_chain(n_stages=2, n_tok=8)
    mod = lower(g)
    names = [n for n, _ in mod.trace]
    assert names == [
        "lower-frontend", "legalize-placement", "eliminate-dead",
        "infer-fifo-depths", "analyze-rates", "detect-sdf-regions",
        "streamcheck", "fuse-sdf-regions", "fuse-sdf-host-regions",
    ]
    assert "module chain" in mod.dump_trace("lower-frontend")
    with pytest.raises(KeyError):
        mod.dump_trace("no-such-pass")


def test_program_ir_dump():
    from repro.apps.streams import idct8

    net, _ = idct8(8)
    prog = repro.compile(net, backend="device", block=64)
    full = prog.ir_dump()
    assert "// after fuse-sdf-regions" in full
    assert "fused0" in prog.ir_dump("fuse-sdf-regions")
    # before fusion the members are still distinct actors
    assert "idct" in prog.ir_dump("detect-sdf-regions")


# ---------------------------------------------------------------------------
# Placement legalization
# ---------------------------------------------------------------------------


def test_legalize_rejects_unknown_actor():
    g, _ = make_topfilter(n=16)
    xcf = make_xcf(g.name, {"source": "t0", "filter": "t0", "sink": "t0",
                            "ghost": "t0"})
    with pytest.raises(GraphError, match="unknown actor 'ghost'"):
        legalize_xcf(g, xcf)


def test_legalize_rejects_unassigned():
    g, _ = make_topfilter(n=16)
    xcf = make_xcf(g.name, {"source": "t0", "filter": "t0"})
    with pytest.raises(GraphError, match="unassigned"):
        legalize_xcf(g, xcf)


def test_legalize_rejects_host_only_on_hw():
    g, _ = make_topfilter(n=16, vectorized=True)
    xcf = make_xcf(g.name, {"source": "accel", "filter": "t0", "sink": "t0"})
    with pytest.raises(GraphError, match="host-only"):
        legalize_xcf(g, xcf)


def test_legalize_accepts_two_hw_partitions():
    """Multi-accelerator placements are configuration, not an error: each hw
    partition becomes its own region (compiled into its own device program
    behind its own PLink lane)."""
    g, _ = make_chain(n_stages=2, n_tok=8)
    xcf = make_xcf(g.name, {"src": "t0", "s0": "acc_a", "s1": "acc_b",
                            "snk": "t0"}, accel=("acc_a", "acc_b"))
    mod = legalize_xcf(g, xcf)
    assert [r.id for r in mod.hw_regions()] == ["acc_a", "acc_b"]
    assert mod.hw_assignment() == {"s0": "acc_a", "s1": "acc_b"}
    # the single-partition accessor refuses to pick one arbitrarily
    with pytest.raises(GraphError, match="hw_regions"):
        mod.hw_region


def test_legalize_rejects_unknown_code_generator():
    """An XCF partition whose code generator the toolchain does not provide
    must fail loudly, naming the partition and the known set — it used to
    fall through as an unscheduled pseudo-thread."""
    g, _ = make_chain(n_stages=2, n_tok=8)
    xcf = make_xcf(g.name, {"src": "t0", "s0": "fpga0", "s1": "t0",
                            "snk": "t0"})
    xcf.partitions["fpga0"].code_generator = "vivado-hls"
    with pytest.raises(GraphError) as e:
        legalize_xcf(g, xcf)
    assert "'fpga0'" in str(e.value)
    assert "vivado-hls" in str(e.value)
    assert "sw" in str(e.value) and "hw" in str(e.value)


def test_legalize_rejects_object_dtype_on_device():
    g = ActorGraph("objnet")
    g.add(simple_actor("a", lambda st, v: (st, v), dtype="object"))
    g.add(simple_actor("b", lambda st, v: (st, v), dtype="object"))
    src = Actor("src", outputs=[Port("OUT", "object")],
                actions=[Action("g", produces={"OUT": 1},
                                fire=lambda st, t: (st, {"OUT": [1]}))])
    snk = Actor("snk", inputs=[Port("IN", "object")],
                actions=[Action("e", consumes={"IN": 1},
                                fire=lambda st, t: (st, {}))])
    g.add(src)
    g.add(snk)
    g.connect("src", "a")
    g.connect("a", "b")
    g.connect("b", "snk")
    xcf = make_xcf(g.name, {"src": "t0", "a": "accel", "b": "accel",
                            "snk": "t0"})
    with pytest.raises(GraphError, match="cannot be staged"):
        legalize_xcf(g, xcf)


def test_device_dtype_ok():
    assert device_dtype_ok("float32")
    assert device_dtype_ok("int32")
    assert device_dtype_ok("bfloat16")
    assert not device_dtype_ok("object")


# ---------------------------------------------------------------------------
# Dead-actor/channel elimination
# ---------------------------------------------------------------------------


def test_dead_cycle_eliminated():
    g, _ = make_chain(n_stages=1, n_tok=8)
    # a 2-cycle that reaches no sink: valid (all ports connected) but dead
    g.add(simple_actor("loop_a", lambda st, v: (st, v)))
    g.add(simple_actor("loop_b", lambda st, v: (st, v)))
    g.connect("loop_a", "loop_b")
    g.connect("loop_b", "loop_a")
    mod = lower(g)
    assert "loop_a" not in mod.actors and "loop_b" not in mod.actors
    assert mod.meta["eliminated"] == ["loop_a", "loop_b"]
    assert all(
        ch.src not in ("loop_a", "loop_b") and ch.dst not in ("loop_a", "loop_b")
        for ch in mod.channels
    )
    # region membership is pruned too, and the live path still runs
    assert set(mod.assignment()) == set(mod.actors)
    from repro.runtime.scheduler import HostRuntime

    HostRuntime(mod).run_single()


def test_dead_region_fed_by_live_actor_is_kept():
    """Removing a dead region that consumes from a live actor would leave
    the live producer's output port dangling — it must be kept instead."""
    g = ActorGraph("fed_dead")

    def gen(st):
        x = st.get("i", 0)
        return {"i": x + 1}, float(x)

    from repro.core.actor import sink_actor, source_actor

    g.add(source_actor("src", gen, has_next=lambda st: st.get("i", 0) < 8))
    # live tee-like actor: one output to the sink, one into a dead cycle
    g.add(Actor(
        "t", inputs=[Port("IN", "float32")],
        outputs=[Port("O0", "float32"), Port("O1", "float32")],
        actions=[Action("d", consumes={"IN": 1},
                        produces={"O0": 1, "O1": 1},
                        fire=lambda st, tk: (st, {"O0": [tk["IN"][0]],
                                                  "O1": [tk["IN"][0]]}))],
    ))
    g.add(simple_actor("loop_a", lambda st, v, w: (st, v + w),
                       inputs=("I0", "I1"), outputs=("O0",)))
    g.add(simple_actor("loop_b", lambda st, v: (st, v)))
    got = []
    g.add(sink_actor("snk", lambda st, v: (got.append(float(v)), st)[1]))
    g.connect("src", "t", "OUT", "IN")
    g.connect("t", "snk", "O0", "IN")
    g.connect("t", "loop_a", "O1", "I0")    # live actor feeds the dead region
    g.connect("loop_a", "loop_b", "O0", "IN")
    g.connect("loop_b", "loop_a", "OUT", "I1")
    mod = lower(g)
    assert "eliminated" not in mod.meta
    assert set(mod.actors) == {"src", "t", "loop_a", "loop_b", "snk"}
    from repro.runtime.scheduler import HostRuntime

    HostRuntime(mod).run_single()
    assert got == [float(v) for v in range(8)]


def test_no_sinks_left_untouched():
    g = ActorGraph("cycleonly")
    g.add(simple_actor("a", lambda st, v: (st, v)))
    g.add(simple_actor("b", lambda st, v: (st, v)))
    g.connect("a", "b")
    g.connect("b", "a")
    mod = lower(g)
    assert set(mod.actors) == {"a", "b"}


# ---------------------------------------------------------------------------
# FIFO depth inference
# ---------------------------------------------------------------------------


def test_depth_priority_xcf_over_authored_over_inferred():
    g, _ = make_topfilter(n=16)
    xcf = make_xcf(g.name, {a: "t0" for a in g.actors})
    xcf.connections.append(ConnectionSpec("source", "OUT", "filter", "IN", 8))
    mod = lower(g, xcf, default_depth=512)
    by_key = {ch.key: ch for ch in mod.channels}
    pinned = by_key[("source", "OUT", "filter", "IN")]
    assert pinned.resolved_depth == 8 and pinned.depth_source() == "xcf"
    rest = by_key[("filter", "OUT", "sink", "IN")]
    assert rest.resolved_depth == 512 and rest.depth_source() == "inferred"


def test_depth_authored_wins_over_inferred():
    g = ActorGraph("authored")
    g.add(simple_actor("a", lambda st, v: (st, v)))
    src = Actor("src", outputs=[Port("OUT", "float32")],
                actions=[Action("g", produces={"OUT": 1},
                                fire=lambda st, t: (st, {"OUT": [1.0]}))])
    snk = Actor("snk", inputs=[Port("IN", "float32")],
                actions=[Action("e", consumes={"IN": 1},
                                fire=lambda st, t: (st, {}))])
    g.add(src)
    g.add(snk)
    g.connect("src", "a", depth=32)
    g.connect("a", "snk")
    mod = lower(g, default_depth=256)
    by_key = {ch.key: ch for ch in mod.channels}
    assert by_key[("src", "OUT", "a", "IN")].resolved_depth == 32
    assert by_key[("a", "OUT", "snk", "IN")].resolved_depth == 256


def test_depth_device_boundary_gets_double_buffer():
    g, _ = make_chain(n_stages=2, n_tok=8)
    xcf = make_xcf(g.name, {"src": "t0", "s0": "accel", "s1": "accel",
                            "snk": "t0"})
    # megastep off: a crossing channel double-buffers one block
    mod = lower(g, xcf, default_depth=256, block=1024, megastep=False)
    assert mod.channels, "expected boundary channels"
    for ch in mod.channels:
        assert ch.resolved_depth == 2048, str(ch)


def test_depth_device_boundary_sized_for_megastep():
    g, _ = make_chain(n_stages=2, n_tok=8)
    xcf = make_xcf(g.name, {"src": "t0", "s0": "accel", "s1": "accel",
                            "snk": "t0"})
    # default megastep target k=4: crossing channels absorb 2*k*block so a
    # pipelined megastep launch never clamps
    mod = lower(g, xcf, default_depth=256, block=1024)
    assert mod.meta["megastep"] == 4
    for ch in mod.channels:
        assert ch.resolved_depth == 8192, str(ch)
    # an explicit integer target scales the same way
    mod3 = lower(g, xcf, default_depth=256, block=1024, megastep=2)
    assert mod3.meta["megastep"] == 2
    for ch in mod3.channels:
        assert ch.resolved_depth == 4096, str(ch)


# ---------------------------------------------------------------------------
# SDF detection + fusion plumbing
# ---------------------------------------------------------------------------


def test_sdf_region_detected_and_fused():
    g, _ = make_chain(n_stages=3, n_tok=64)
    xcf = make_xcf(g.name, {"src": "t0", "s0": "accel", "s1": "accel",
                            "s2": "accel", "snk": "t0"})
    mod = lower(g, xcf)
    assert mod.meta["sdf_groups"] == [["s0", "s1", "s2"]]
    hw = mod.hw_region
    assert hw.actors == ["fused0"]
    fa = mod.actors["fused0"]
    assert fa.is_fused and fa.fused_from == ("s0", "s1", "s2")
    assert fa.codegen == "jnp"  # plain lambdas carry no stream_op specs
    # boundary channels rewired to the fused actor's renamed ports
    ports = {(ch.src, ch.src_port, ch.dst, ch.dst_port) for ch in mod.channels}
    assert ("src", "OUT", "fused0", "s0__IN") in ports
    assert ("fused0", "s2__OUT", "snk", "IN") in ports


def test_fuse_off_keeps_actors():
    g, _ = make_chain(n_stages=3, n_tok=64)
    xcf = make_xcf(g.name, {"src": "t0", "s0": "accel", "s1": "accel",
                            "s2": "accel", "snk": "t0"})
    mod = lower(g, xcf, fuse=False)
    assert sorted(mod.hw_region.actors) == ["s0", "s1", "s2"]
    assert "fused" not in mod.meta


def test_dynamic_actor_not_fused():
    """A dynamic-rate (guarded, multi-action) actor stays out of SDF groups."""
    g, _ = make_topfilter(n=64, vectorized=True)
    xcf = make_xcf(g.name, {"source": "t0", "filter": "accel", "sink": "t0"})
    mod = lower(g, xcf)
    assert "sdf_groups" not in mod.meta
    assert mod.hw_region.actors == ["filter"]


def test_non_convex_sdf_group_not_fused():
    """Two static actors joined directly AND through a dynamic actor: fusing
    them would put the dynamic actor both upstream and downstream of the
    fused region (a cycle).  The pass must skip the group, and the program
    must still compile and run correctly."""
    g = ActorGraph("nonconvex")

    def gen(st):
        x = st.get("i", 0)
        return {"i": x + 1}, float(x)

    from repro.core.actor import sink_actor, source_actor

    g.add(source_actor("src", gen, has_next=lambda st: st.get("i", 0) < 32))

    def a_vf(state, ins):
        v, m = ins["IN"]
        return state, {"O0": (v, m), "O1": (v, m)}

    g.add(Actor(
        "a", inputs=[Port("IN", "float32")],
        outputs=[Port("O0", "float32"), Port("O1", "float32")],
        actions=[Action("d", consumes={"IN": 1},
                        produces={"O0": 1, "O1": 1},
                        fire=lambda st, t: (st, {"O0": [t["IN"][0]],
                                                 "O1": [t["IN"][0]]}))],
        vector_fire=a_vf,
    ))
    # dynamic (two actions -> not SDF) but device-eligible passthrough
    g.add(Actor(
        "b", inputs=[Port("IN", "float32")], outputs=[Port("OUT", "float32")],
        actions=[
            Action("t0", consumes={"IN": 1}, produces={"OUT": 1},
                   guard=lambda st, t: t["IN"][0] >= 0,
                   fire=lambda st, t: (st, {"OUT": [t["IN"][0]]})),
            Action("t1", consumes={"IN": 1}, fire=lambda st, t: (st, {})),
        ],
        vector_fire=lambda state, ins: (state, {"OUT": ins["IN"]}),
    ))

    def c_vf(state, ins):
        v0, m0 = ins["I0"]
        v1, _ = ins["I1"]
        return state, {"OUT": (v0 + v1, m0)}

    g.add(Actor(
        "c", inputs=[Port("I0", "float32"), Port("I1", "float32")],
        outputs=[Port("OUT", "float32")],
        actions=[Action("s", consumes={"I0": 1, "I1": 1},
                        produces={"OUT": 1},
                        fire=lambda st, t: (st, {"OUT": [t["I0"][0]
                                                         + t["I1"][0]]}))],
        vector_fire=c_vf,
    ))
    got = []
    g.add(sink_actor("snk", lambda st, v: (got.append(float(v)), st)[1]))
    g.connect("src", "a", "OUT", "IN")
    g.connect("a", "c", "O0", "I0")     # direct static->static edge
    g.connect("a", "b", "O1", "IN")     # ... and via the dynamic actor
    g.connect("b", "c", "OUT", "I1")
    g.connect("c", "snk", "OUT", "IN")

    xcf = make_xcf(g.name, {"src": "t0", "a": "accel", "b": "accel",
                            "c": "accel", "snk": "t0"})
    mod = lower(g, xcf, block=16)
    assert "sdf_groups" not in mod.meta
    assert mod.meta["sdf_groups_skipped"] == [["a", "c"]]
    assert sorted(mod.hw_region.actors) == ["a", "b", "c"]

    prog = repro.compile(g, xcf, block=16)
    prog.run()
    assert got == [2.0 * v for v in range(32)]


def test_runtime_rejects_module_plus_mapping():
    from repro.runtime.scheduler import HostRuntime

    g, _ = make_chain(n_stages=1, n_tok=8)
    mod = lower(g)
    with pytest.raises(ValueError, match="already fixes"):
        HostRuntime(mod, {"src": "t0"})


def test_partitioner_emits_legal_xcfs():
    """explore() legalizes every design point through the pipeline."""
    from repro.core.partitioner import explore
    from repro.core.profiler import profile_host

    g, _ = make_topfilter(n=512, vectorized=True)
    prof, _ = profile_host(g)
    pts = explore(g, prof, thread_counts=(1, 2), accel_options=(False, True))
    assert pts
    for p in pts:
        legalize_xcf(g, p.xcf)  # must not raise
