"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.ops import grouped_matmul
from repro.kernels.moe_gmm.ref import grouped_matmul_ref
from repro.kernels.quant.ops import dequantize_int8, quantize_int8
from repro.kernels.quant.ref import quantize_int8_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize(
    "B,S,H,KV,hd,causal,dtype",
    [
        (2, 256, 4, 2, 64, True, jnp.float32),
        (1, 512, 8, 8, 128, True, jnp.float32),
        (2, 128, 6, 3, 64, False, jnp.float32),
        (1, 256, 4, 1, 64, True, jnp.bfloat16),
    ],
)
def test_flash_attention(B, S, H, KV, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    ref = attention_ref(qf, kf, vf, causal=causal)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("B,S,H,KV,hd", [(1, 256, 4, 2, 64), (2, 128, 6, 3, 32)])
def test_flash_attention_backward(B, S, H, KV, hd):
    """Custom-VJP flash backward vs autodiff of the reference."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    do = jax.random.normal(ks[3], (B, S, H, hd))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, interpret=True) * do)

    def f_ref(q, k, v):
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
        o = attention_ref(qf, kf, vf, causal=True)
        return jnp.sum(o.reshape(B, H, S, hd).transpose(0, 2, 1, 3) * do)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4
        )


@pytest.mark.parametrize(
    "B,S,nh,P,N,chunk",
    [(2, 256, 4, 32, 16, 64), (1, 128, 2, 64, 128, 32), (2, 64, 3, 16, 8, 64)],
)
def test_ssd_scan(B, S, nh, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, nh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, N)) * 0.3
    C_ = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y, st = ssd_scan(x, dt, A, B_, C_, chunk=chunk, interpret=True)
    xf = x.transpose(0, 2, 1, 3).reshape(B * nh, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * nh, S)
    daf = dtf * jnp.repeat(A[None, :], B, 0).reshape(B * nh)[:, None]
    yr, sr = ssd_ref(xf, dtf, daf, B_, C_, nheads=nh)
    np.testing.assert_allclose(
        np.asarray(y),
        np.asarray(yr.reshape(B, nh, S, P).transpose(0, 2, 1, 3)),
        atol=2e-3, rtol=2e-3,
    )
    np.testing.assert_allclose(
        np.asarray(st.reshape(B * nh, P, N)), np.asarray(sr), atol=2e-3, rtol=2e-3
    )


@pytest.mark.parametrize(
    "E,C,d,f,dtype",
    [
        (4, 128, 256, 128, jnp.float32),
        (8, 256, 512, 384, jnp.float32),
        (2, 128, 128, 256, jnp.bfloat16),
    ],
)
def test_grouped_matmul(E, C, d, f, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    w = jax.random.normal(ks[1], (E, d, f), dtype) * 0.05
    out = grouped_matmul(x, w, interpret=True)
    ref = grouped_matmul_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize(
    "shape,dtype",
    [((512, 768), jnp.float32), ((4, 100, 256), jnp.bfloat16), ((8, 64), jnp.float32)],
)
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jnp.linspace(0.5, 1.5, shape[-1]).astype(jnp.float32)
    out = rmsnorm(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_model_kernel_path_matches_jnp_path():
    """cfg.use_pallas='interpret' must be numerically equivalent to the chunked
    jnp attention path inside the full model (this equivalence check caught a
    GQA head-summing bug in the jnp path — keep it tight)."""
    import dataclasses

    from repro.configs import get_config
    from repro.model import lm
    from repro.model.attention import attention

    cfg = get_config("smollm-135m").reduced()
    assert cfg.num_kv_heads >= 2  # grouped-query structure preserved
    cfg32 = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    params = lm.init_model(cfg32, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda a: a[0], params["layers"]["pos0"])["mixer"]
    B, S = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg32.d_model))
    pos = jnp.arange(S, dtype=jnp.int32)
    y_jnp, _ = attention(p0, x, cfg32, pos)
    y_krn, _ = attention(
        p0, x, dataclasses.replace(cfg32, use_pallas="interpret"), pos
    )
    np.testing.assert_allclose(
        np.asarray(y_jnp), np.asarray(y_krn), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize("shape", [(64, 1024), (3, 50, 128)])
def test_quant_roundtrip(shape):
    x = jax.random.normal(KEY, shape, jnp.float32) * 3
    q, s = quantize_int8(x, interpret=True)
    qr, sr = quantize_int8_ref(x)
    assert (np.asarray(q) == np.asarray(qr)).mean() > 0.999
    xd = dequantize_int8(q, s)
    rel = float(jnp.max(jnp.abs(xd - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01  # 8-bit per-row error bound
