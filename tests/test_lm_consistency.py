"""Decode-path consistency: prefill + token-by-token decode must reproduce the
full-sequence forward logits (validates KV caches, SSD state carry, ring
windows, RoPE positions)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.model import lm


def full_logits(params, cfg, tokens):
    hidden, _, _ = lm.forward_hidden(params, cfg, tokens)
    w = params["head"]["w"] if "head" in params else params["embed"]["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", hidden.astype(jnp.float32),
                        w.astype(jnp.float32))
    idx = jnp.arange(cfg.padded_vocab)
    return logits + jnp.where(idx < cfg.vocab_size, 0.0, -1e30)


@pytest.mark.parametrize(
    "arch", ["smollm-135m", "jamba-v0.1-52b", "mamba2-130m", "deepseek-moe-16b"]
)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    B, S0, S = 2, 8, 16
    key = jax.random.PRNGKey(1)
    params = lm.init_model(cfg, key)
    tokens = jax.random.randint(key, (B, S), 3, cfg.vocab_size).astype(jnp.int32)

    ref = full_logits(params, cfg, tokens)  # (B, S, Vp)

    # prefill on the first S0 tokens
    logits_p, cache = lm.prefill(params, cfg, tokens=tokens[:, :S0])
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(ref[:, S0 - 1]), atol=2e-2, rtol=2e-2
    )

    # splice into a decode cache sized for the full sequence
    big = lm.init_cache(cfg, B, S)

    def splice(b, s):
        if b.shape == s.shape:
            return s.astype(b.dtype)
        pad = [(0, x - y) for x, y in zip(b.shape, s.shape)]
        return jnp.pad(s.astype(b.dtype), pad)

    cache = jax.tree.map(splice, big, cache)

    # decode the rest one token at a time, teacher-forced
    step = jax.jit(lambda p, c, t, i: lm.decode_step(p, cfg, c, t, i))
    for i in range(S0, S):
        logits, cache = step(params, cache, tokens[:, i], jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref[:, i]), atol=3e-2, rtol=3e-2,
            err_msg=f"{arch} pos {i}",
        )


def test_sliding_window_ring_cache():
    """Jamba-style windowed attention: ring cache beyond the window must match a
    model evaluated with the same window on the full sequence."""
    cfg = get_config("jamba-v0.1-52b").reduced()  # sliding_window=64 in reduced
    assert cfg.sliding_window == 64
    # with S < window the ring cache behaves like a full cache (covered above);
    # here check decode runs past the window boundary without shape errors
    B, W = 1, cfg.sliding_window
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    cache = lm.init_cache(cfg, B, W)  # window-sized => ring mode
    step = jax.jit(lambda p, c, t, i: lm.decode_step(p, cfg, c, t, i))
    tok = jnp.zeros((B,), jnp.int32)
    for i in [0, 1, W - 1, W, W + 1, 2 * W + 3]:
        logits, cache = step(params, cache, tok, jnp.int32(i))
        assert bool(jnp.all(jnp.isfinite(logits)))
