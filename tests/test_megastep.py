"""Megastep launches: k repetition-vector iterations per device dispatch.

Covers the persistent device-resident streaming contract:

  * k resolution ("auto"/int/False) and per-partition clamping (stateful
    regions, shallow crossing FIFOs, no-input partitions),
  * megastep ≡ per-iteration, bitwise, on every Table-I network and on
    both megastep lowerings (flat Pallas grid / lax.scan),
  * donated-state discipline: state futures chain launch-to-launch and a
    donated tree is never read again host-side,
  * staging-buffer reuse (PLink ring + serve-mode DeviceStage),
  * the stage/dispatch/sync/retire boundary-stats split,
  * serve(): megastep placements hot-swap mid-stream without loss, and
    batched megastep lanes match sequential runs bitwise.
"""

import time

import jax
import numpy as np
import pytest

import repro
from repro.analysis import run_streamcheck
from repro.apps.streams import NETWORKS
from repro.core.actor import simple_actor, sink_actor, source_actor
from repro.core.graph import ActorGraph
from repro.core.xcf import ConnectionSpec, make_xcf
from repro.frontend.program import synthesize_xcf
from repro.ir.passes import DEFAULT_MEGASTEP_K, lower, resolve_megastep

BLOCK = 64

SIZES = {  # small per-network workloads: enough for several megastep launches
    "TopFilter": 900,
    "FIR32": 600,
    "Bitonic8": 48,
    "IDCT8": 48,
    "ZigZag": 9,
}


def _build(name, size):
    builder = NETWORKS[name]
    return builder(size) if name != "FIR32" else builder(n=size)


def _chain_graph(n_tok=600, stateful=False):
    """source -> dev (device-eligible) -> sink, integer-exact values."""
    g = ActorGraph("mega")

    def gen(stt):
        i = stt.get("i", 0)
        if i >= n_tok:
            return stt, None
        return {"i": i + 1}, float(i % 7 - 3)

    g.add(source_actor("source", gen,
                       has_next=lambda stt: stt.get("i", 0) < n_tok))
    if stateful:
        # running sum: small ints stay exact in float32, so host float64
        # and device float32 agree bitwise
        def fn(stt, v):
            acc = stt.get("acc", 0.0) + v
            return {"acc": acc}, acc

        g.add(simple_actor("dev", fn, state={"acc": 0.0}))
    else:
        g.add(simple_actor("dev", lambda stt, v: (stt, v * 2.0 + 1.0)))
    got = []
    g.add(sink_actor("sink", lambda stt, v: (got.append(float(v)), stt)[1]))
    g.connect("source", "dev")
    g.connect("dev", "sink")
    xcf = make_xcf(g.name, {"source": "t0", "dev": "accel", "sink": "t0"})
    return g, got, xcf


# ---------------------------------------------------------------------------
# k resolution + clamping
# ---------------------------------------------------------------------------


def test_resolve_megastep_values():
    assert resolve_megastep(None) == 1
    assert resolve_megastep(False) == 1
    assert resolve_megastep("auto") == DEFAULT_MEGASTEP_K
    assert resolve_megastep(3) == 3
    assert resolve_megastep(0) == 1  # floor at 1


def test_megastep_k_on_compiled_partitions():
    net, _ = _build("FIR32", 400)
    p = repro.compile(net, backend="device", block=BLOCK, megastep=3)
    (prog,) = p.device_programs().values()
    assert prog.megastep_k == 3
    assert prog.flat_megastep  # FIR fuses to one Pallas stream region
    assert prog.megastep is not None and prog.raw_megastep is not None
    # megastep disabled: classic one-block step only
    p1 = repro.compile(net, backend="device", block=BLOCK, megastep=False)
    (prog1,) = p1.device_programs().values()
    assert prog1.megastep_k == 1 and prog1.megastep is None


def test_stateful_partition_clamps_to_one():
    g, _got, xcf = _chain_graph(stateful=True)
    p = repro.compile(g, xcf, block=BLOCK, megastep=4)
    (prog,) = p.device_programs().values()
    # the block scan advances actor state over padding positions, so only
    # all-stateless partitions keep megastep ≡ per-iteration on ragged tails
    assert prog.megastep_k == 1


def test_shallow_crossing_fifo_clamps_k():
    g, _got, xcf = _chain_graph()
    # pin both crossing FIFOs to 2 blocks: floor(k) = depth // (2*block) = 1
    xcf.connections.append(
        ConnectionSpec("source", "OUT", "dev", "IN", 2 * BLOCK))
    xcf.connections.append(
        ConnectionSpec("dev", "OUT", "sink", "IN", 2 * BLOCK))
    p = repro.compile(g, xcf, block=BLOCK, megastep=4, check="warn")
    (prog,) = p.device_programs().values()
    assert prog.megastep_k == 1
    # ... and streamcheck names the clamp (SB206, warning not error)
    diags = [d for d in p.check() if d.code == "SB206"]
    assert diags and all(d.severity == "warning" for d in diags)


def test_inferred_depths_scale_with_k_so_no_sb206():
    g, _got, xcf = _chain_graph()
    mod = lower(g, xcf, block=BLOCK, megastep=4)
    assert mod.meta["megastep"] == 4
    for ch in mod.channels:
        assert ch.resolved_depth >= 2 * 4 * BLOCK
    assert not [d for d in run_streamcheck(mod, block=BLOCK)
                if d.code == "SB206"]


# ---------------------------------------------------------------------------
# bitwise: megastep == per-iteration on every Table-I network
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(NETWORKS))
@pytest.mark.parametrize("k", [2, 5])
def test_megastep_bitwise_per_iteration(name, k):
    net, got = _build(name, SIZES[name])
    repro.compile(net, backend="device", block=BLOCK, megastep=False).run()
    ref = list(got)
    got.clear()
    p = repro.compile(net, backend="device", block=BLOCK, megastep=k)
    p.run()
    assert got == ref, (name, k, got[:8], ref[:8])


def test_megastep_bitwise_unfused_scan_path(k=3):
    """fuse=False forces the lax.scan megastep (per-actor step body)."""
    name = "FIR32"
    net, got = _build(name, 400)
    repro.compile(net, backend="device", block=BLOCK, fuse=False,
                  megastep=False).run()
    ref = list(got)
    got.clear()
    p = repro.compile(net, backend="device", block=BLOCK, fuse=False,
                      megastep=k)
    (prog,) = p.device_programs().values()
    assert prog.megastep_k == k and not prog.flat_megastep
    p.run()
    assert got == ref


def test_stateful_chain_pipelined_launches_bitwise():
    """Launch-time state chaining under donation: a stateful device actor
    (k clamps to 1, but launches still pipeline 2-deep) must produce the
    host stream exactly."""
    g, got, _xcf = _chain_graph(n_tok=2000, stateful=True)
    repro.compile(g, backend="host").run()
    ref = list(got)
    got.clear()
    g2, got2, xcf2 = _chain_graph(n_tok=2000, stateful=True)
    repro.compile(g2, xcf2, block=BLOCK).run()
    assert got2 == ref


# ---------------------------------------------------------------------------
# donated-state discipline
# ---------------------------------------------------------------------------


def test_donated_state_is_never_read_after_donation():
    g, _got, xcf = _chain_graph(stateful=True)
    p = repro.compile(g, xcf, block=BLOCK)
    (prog,) = p.device_programs().values()
    assert prog.donate

    def ins(v=1.0):
        vals = np.full((BLOCK,), v, np.float32)
        mask = np.ones((BLOCK,), bool)
        return {"dev.IN": (vals, mask)}

    st1, outs1, _ = prog.step(prog.init_state, ins())
    # chain: st1's tree is donated into the second launch
    st2, outs2, _ = prog.step(st1, ins())
    np.asarray(outs2["dev.OUT"][0])  # force completion
    if jax.default_backend() != "cpu":
        # on accelerators donation really deletes the buffer: reading the
        # donated tree must raise, proving no host-side alias survives
        with pytest.raises(RuntimeError):
            np.asarray(jax.tree.leaves(st1)[0])
    # the chained state is live and correct either way
    assert np.asarray(jax.tree.leaves(st2)[0]).shape == ()


def test_plink_retire_does_not_touch_state():
    """PLink updates self.state at LAUNCH time (to the async state future)
    and _retire takes only (outs, idle) — writing state at retirement would
    hand an already-donated tree to the next launch."""
    import inspect

    from repro.runtime.plink import PLink

    sig = inspect.signature(PLink._retire)
    assert list(sig.parameters) == ["self", "outs", "idle"]


def test_plink_staging_ring_and_stats_split():
    g, got, xcf = _chain_graph(n_tok=1200)
    p = repro.compile(g, xcf, block=BLOCK, megastep=2)
    rt = p._build_runtime()
    rt.run_threads()
    (plink,) = rt.plinks.values()
    k = plink.program.megastep_k
    assert k == 2
    # quad-buffered staging ring of preallocated (k, block) buffers
    assert len(plink._slots) == 4
    for slot in plink._slots:
        (arr, mask) = slot["dev.IN"]
        assert arr.shape == (k, BLOCK) and mask.shape == (k, BLOCK)
    s = plink.stats
    assert s.launches >= 1200 // (k * BLOCK)
    assert s.stage_ns > 0 and s.dispatch_ns > 0
    # legacy aggregates remain consistent with the split
    assert s.h2d_ns == s.stage_ns + s.dispatch_ns
    assert s.d2h_ns == s.sync_ns + s.retire_ns
    assert len(got) == 1200


def test_device_stage_reuses_staging_buffers():
    from repro.serve_stream.session import DeviceStage

    g, _got, xcf = _chain_graph(n_tok=400)
    p = repro.compile(g, xcf, block=BLOCK, megastep=2)
    (prog,) = p.device_programs().values()
    stage = DeviceStage(prog, p.module)
    from repro.runtime.fifo import RingFifo, ReaderEndpoint, WriterEndpoint

    fin = RingFifo(prog.megastep_k * 2 * BLOCK, "in", deferred=False)
    fout = RingFifo(prog.megastep_k * 2 * BLOCK, "out", deferred=False)
    stage.in_eps["dev.IN"] = ReaderEndpoint(fin)
    stage.out_eps["dev.OUT"] = WriterEndpoint(fout)
    fin.write([float(i) for i in range(BLOCK)])
    pay1 = stage.stage()
    assert pay1 is not None
    assert pay1["dev.IN"][0].shape == (2, BLOCK)
    # everything queued was drained into the buffers: nothing to repack
    assert stage.stage() is None
    state, outs, _ = prog.launch(stage.state, {
        kk: (np.asarray(v), np.asarray(m)) for kk, (v, m) in pay1.items()
    })
    # what the batcher does at launch/retire: rebind the state future,
    # count the in-flight round, then retire outputs only
    stage.state = state
    stage.inflight += 1
    stage.retire(outs)
    assert stage.inflight == 0
    fin.write([float(i) for i in range(BLOCK)])
    pay2 = stage.stage()
    # identical buffer objects: preallocated, reused, not reallocated
    assert pay2["dev.IN"][0] is pay1["dev.IN"][0]
    assert pay2["dev.IN"][1] is pay1["dev.IN"][1]


# ---------------------------------------------------------------------------
# serve(): hot swap + batched lanes under megastep
# ---------------------------------------------------------------------------


def _drain_source(graph, name="source"):
    actor = graph.actors[name]
    action = actor.actions[0]
    state = dict(actor.initial_state)
    out = []
    while action.guard is None or action.guard(state, {}):
        state, produced = action.fire(state, {})
        vals = produced.get(actor.outputs[0].name, [])
        if not vals:
            break
        out.extend(vals)
    return out


def test_serve_batched_megastep_bitwise():
    name = "TopFilter"
    net, got = _build(name, 900)
    prog = repro.compile(net, backend="device", block=BLOCK, megastep=3)
    stream = _drain_source(prog.graph)
    prog.run()
    ref = list(got)
    net2, _ = _build(name, 900)
    prog2 = repro.compile(net2, backend="device", block=BLOCK, megastep=3)
    with prog2.serve(batching=True) as server:
        sessions = [server.open_session() for _ in range(3)]
        for s in sessions:
            s.submit(stream)
            s.close()
        assert server.drain(timeout=120)
        for s in sessions:
            assert s.output() == ref


def test_hot_swap_preserves_megastep_state_bitwise():
    """A mid-stream swap away from (and implicitly back through) a megastep
    device placement must lose nothing and reorder nothing — the transplant
    carries device state across the placement change bit-identically."""
    name = "TopFilter"
    net, got = _build(name, 1200)
    prog = repro.compile(net, backend="device", block=BLOCK, megastep=4)
    stream = _drain_source(prog.graph)
    prog.run()
    ref = list(got)
    net2, _ = _build(name, 1200)
    prog2 = repro.compile(net2, backend="device", block=BLOCK, megastep=4)
    with prog2.serve() as server:
        ss = [server.open_session() for _ in range(2)]
        for s in ss:
            s.submit(stream[:600])
        time.sleep(0.05)  # let tokens flow through the megastep placement
        server.request_repartition(synthesize_xcf(prog2.graph, "host"))
        for s in ss:
            s.submit(stream[600:])
            s.close()
        assert server.drain(timeout=120)
        for s in ss:
            assert s.output() == ref
        assert server.telemetry.lifetime().swaps == 1
