"""MILP formulation + solver cross-validation."""

import math

import pytest
from helpers import given, settings, st

from repro.core.cost_model import LinkModel, NetworkProfile, evaluate
from repro.core.graph import ActorGraph
from repro.core.milp import (
    solve_anneal,
    solve_bb,
    solve_chain_dp,
    solve_exact,
)
from repro.core.actor import simple_actor, sink_actor, source_actor


def chain_graph(n=5):
    g = ActorGraph("g")

    def gen(st):
        return st, None

    g.add(source_actor("src", gen))
    prev = "src"
    for i in range(n):
        g.add(simple_actor(f"a{i}", lambda st, v: (st, v)))
        g.connect(prev, f"a{i}")
        prev = f"a{i}"
    g.add(sink_actor("snk", lambda st, v: st))
    g.connect(prev, "snk")
    return g


def make_profile(g, sw, hw, tokens=1000):
    prof = NetworkProfile()
    for i, a in enumerate(sorted(g.actors)):
        prof.exec_sw[a] = sw[i % len(sw)]
        prof.exec_hw[a] = hw[i % len(hw)]
    for ch in g.channels:
        prof.tokens[ch.key] = tokens
        prof.buffers[ch.key] = 256
    return prof


def test_tau_equation4():
    link = LinkModel("l", 1e-6, 1e9, token_bytes=4)
    # n <= b: single transfer
    assert link.tau(100, 256) == pytest.approx(link.xi(100))
    # n > b: floor(n/b) full buffers + remainder
    n, b = 1000, 256
    want = link.xi(b) * (n // b) + link.xi(n % b)
    assert link.tau(n, b) == pytest.approx(want)
    # monotone in n
    assert link.tau(2000, 256) > link.tau(1000, 256)


def test_evaluate_prefers_parallel_threads():
    g = chain_graph(4)
    prof = make_profile(g, sw=[1.0], hw=[10.0])
    one = evaluate(g, {a: "t0" for a in g.actors}, prof)
    two = evaluate(
        g,
        {a: ("t0" if i % 2 else "t1") for i, a in enumerate(sorted(g.actors))},
        prof,
    )
    assert two["T_exec"] < one["T_exec"]


def test_accel_helps_when_fast():
    g = chain_graph(4)
    prof = make_profile(g, sw=[1.0], hw=[0.01])
    sol_sw = solve_exact(g, prof, ["t0", "t1"])
    sol_hw = solve_exact(g, prof, ["t0", "t1", "accel"])
    assert sol_hw.objective < sol_sw.objective
    assert any(p == "accel" for p in sol_hw.assignment.values())


def test_io_actors_never_on_accel():
    g = chain_graph(3)
    prof = make_profile(g, sw=[1.0], hw=[1e-6])
    sol = solve_exact(g, prof, ["t0", "accel"])
    assert sol.assignment["src"] != "accel"
    assert sol.assignment["snk"] != "accel"


def test_bb_matches_exact():
    g = chain_graph(5)
    prof = make_profile(g, sw=[1.0, 2.0, 0.5], hw=[0.2, 0.1])
    e = solve_exact(g, prof, ["t0", "t1", "accel"])
    b = solve_bb(g, prof, ["t0", "t1", "accel"])
    assert b.objective == pytest.approx(e.objective)


@settings(max_examples=15, deadline=None)
@given(
    sw=st.lists(st.floats(0.1, 5.0), min_size=3, max_size=3),
    hw=st.lists(st.floats(0.01, 5.0), min_size=2, max_size=2),
    tokens=st.integers(10, 100000),
)
def test_solvers_agree_property(sw, hw, tokens):
    g = chain_graph(4)
    prof = make_profile(g, sw=sw, hw=hw, tokens=tokens)
    e = solve_exact(g, prof, ["t0", "t1", "accel"])
    b = solve_bb(g, prof, ["t0", "t1", "accel"])
    a = solve_anneal(g, prof, ["t0", "t1", "accel"], iters=4000, restarts=2)
    assert b.objective == pytest.approx(e.objective, rel=1e-9)
    assert a.objective <= e.objective * 1.5 + 1e-9  # heuristic within 1.5x


def test_chain_dp_optimal_vs_bruteforce():

    names = list("abcdef")
    ex = {"a": 3.0, "b": 1.0, "c": 4.0, "d": 1.0, "e": 5.0, "f": 2.0}
    bc = lambda i: 0.25
    stages, T = solve_chain_dp(names, ex, bc, 3)
    # brute force all contiguous splits into <= 3 parts
    best = math.inf
    n = len(names)
    for c1 in range(1, n + 1):
        for c2 in range(c1, n + 1):
            segs = [(0, c1), (c1, c2), (c2, n)]
            segs = [s for s in segs if s[0] < s[1]]
            cost = max(
                sum(ex[names[i]] for i in range(a, b)) + (0.25 if a > 0 else 0)
                for a, b in segs
            )
            best = min(best, cost)
    assert T == pytest.approx(best)
    assert stages == sorted(stages)  # contiguous, monotone
