"""Multi-partition device runtime: N concurrent accelerator partitions.

Covers the tentpole end to end: legalization of k-way device placements,
one fused region per partition in the IR dump, per-partition PLink lanes
(device→device channels over numpy ``ArrayFifo`` lane pairs), bitwise
equivalence of 2-partition placements against the single-partition and
host paths through both ``Program.run()`` and ``Program.serve()``, the
exhaustive small-N placement sweep, multi-lane serving with a mid-stream
single↔multi hot-swap, the multi-accelerator MILP/DSE surface, and the
``runtime_from_xcf`` unknown-code-generator fix.
"""

import numpy as np
import pytest

import repro
from repro.apps.streams import NETWORKS
from repro.core.graph import GraphError
from repro.core.xcf import make_xcf
from repro.runtime.device_runtime import resolve_pe_device
from repro.runtime.fifo import ArrayFifo
from repro.runtime.scheduler import runtime_from_xcf

from helpers import drain_source

BLOCK = 64


# ---------------------------------------------------------------------------
# placement enumeration helpers
# ---------------------------------------------------------------------------


def _eligible(graph):
    return [a for a in graph.topo_order() if graph.actors[a].device_ok]


def _reach(graph, seeds, forward=True):
    edges = {}
    for ch in graph.channels:
        a, b = (ch.src, ch.dst) if forward else (ch.dst, ch.src)
        edges.setdefault(a, set()).add(b)
    out, work = set(), list(seeds)
    while work:
        n = work.pop()
        for m in edges.get(n, ()):
            if m not in out:
                out.add(m)
                work.append(m)
    return out


def _convex(graph, group):
    """No path between two members passes through an outside actor — the
    same convexity rule SDF-region detection applies; a non-convex device
    partition would need an internal wire buffered across launches."""
    group = set(group)
    down = _reach(graph, group, forward=True) - group
    up = _reach(graph, group, forward=False) - group
    return not (down & up)


def legal_two_splits(graph, cap=6):
    """Every legal 2-partition split of the device-eligible actors.

    Exhaustive 2-colorings when the eligible set is small; for larger
    networks (Bitonic8's 24 compare-exchangers would be 2^24 colorings)
    every topological prefix cut — still every cut depth, one order.
    Both sides must be non-empty and convex.
    """
    elig = _eligible(graph)
    n = len(elig)
    splits = []
    if n <= cap:
        for bits in range(1, 2 ** n - 1):
            d0 = {elig[i] for i in range(n) if bits & (1 << i)}
            d1 = set(elig) - d0
            if _convex(graph, d0) and _convex(graph, d1):
                splits.append((sorted(d0), sorted(d1)))
    else:
        for k in range(1, n):
            d0, d1 = set(elig[:k]), set(elig[k:])
            if _convex(graph, d0) and _convex(graph, d1):
                splits.append((sorted(d0), sorted(d1)))
    return splits


def split_xcf(graph, d0, d1, host="t0"):
    asg = {}
    d0, d1 = set(d0), set(d1)
    for a in graph.actors:
        asg[a] = "d0" if a in d0 else "d1" if a in d1 else host
    return make_xcf(graph.name, asg, accel=("d0", "d1"))


def _halves(graph):
    """The canonical half/half split used by the equivalence tests."""
    elig = _eligible(graph)
    k = max(1, len(elig) // 2)
    return elig[:k], elig[k:]


# ---------------------------------------------------------------------------
# IR: one fused region per device partition
# ---------------------------------------------------------------------------


def test_ir_one_fused_region_per_partition():
    net, _ = NETWORKS["FIR32"](n=128)
    g = net.graph()
    d0, d1 = _halves(g)
    prog = repro.compile(net, split_xcf(g, d0, d1), block=BLOCK)
    assert prog.hw_partitions == ["d0", "d1"]
    mod = prog.module
    hw_of = mod.hw_assignment()
    fused = [n for n, a in mod.actors.items() if a.is_fused]
    # exactly one fused actor per device partition, and fusion never
    # crossed the partition boundary
    assert sorted(hw_of[f] for f in fused) == ["d0", "d1"]
    for f in fused:
        members = set(mod.actors[f].fused_from)
        assert members <= (set(d0) if hw_of[f] == "d0" else set(d1))
    # the dump tells the same story per pass
    dump = prog.ir_dump("fuse-sdf-regions")
    assert "region d0 [hw/" in dump and "region d1 [hw/" in dump


def test_device_to_device_channel_is_staged_lane_pair():
    net, _ = NETWORKS["FIR32"](n=128)
    g = net.graph()
    d0, d1 = _halves(g)
    prog = repro.compile(net, split_xcf(g, d0, d1), block=BLOCK)
    rt = prog._build_runtime()
    lanes = [f for f in rt.fifos.values() if isinstance(f, ArrayFifo)]
    # the systolic (x, acc) pair crosses d0 -> d1 as two numpy lanes
    assert len(lanes) == 2
    # each partition has its own PLink on its own scheduler thread
    assert sorted(rt.plinks) == ["d0", "d1"]
    assert {rt.plinks[p].program.partition for p in rt.plinks} == {"d0", "d1"}
    threads_of_plinks = {
        part.name
        for part in rt.partitions.values()
        for inst in part.instances
        if inst in rt.plinks.values()
    }
    assert len(threads_of_plinks) == 2  # independent lanes pipeline


def test_resolve_pe_device():
    import jax

    default = jax.devices()[0]
    assert resolve_pe_device("") is None
    assert resolve_pe_device("x86_64") is None
    assert resolve_pe_device("tpu-v5e-16x16") is default
    plat = default.platform
    assert resolve_pe_device(f"{plat}:0") is default
    # compiled programs carry the binding
    net, _ = NETWORKS["IDCT8"](8)
    prog = repro.compile(net, backend="device", block=BLOCK)
    dp = prog.device_program()
    assert dp.pe == "tpu-v5e-16x16"
    assert dp.device is default


# ---------------------------------------------------------------------------
# Acceptance: FIR32 + ZigZag, 2 partitions == 1 partition == host
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,size", [("FIR32", 256), ("ZigZag", 6)])
def test_two_partition_run_bitwise(name, size):
    net, got = (
        NETWORKS[name](n=size) if name == "FIR32" else NETWORKS[name](size)
    )
    g = net.graph()
    repro.compile(net, backend="host").run()
    host = list(got)
    repro.compile(net, backend="device", block=BLOCK).run()
    single = list(got)
    d0, d1 = _halves(g)
    xcf = split_xcf(g, d0, d1)
    for fuse in (True, False):
        repro.compile(net, xcf, block=BLOCK, fuse=fuse).run()
        assert list(got) == single  # bitwise vs the single-partition path
    np.testing.assert_allclose(single, host, rtol=1e-5, atol=1e-4)
    if name == "ZigZag":  # integer-exact ops: bitwise across everything
        assert single == host


@pytest.mark.parametrize("name,size", [("FIR32", 256), ("ZigZag", 6)])
def test_two_partition_serve_bitwise(name, size):
    net, got = (
        NETWORKS[name](n=size) if name == "FIR32" else NETWORKS[name](size)
    )
    g = net.graph()
    d0, d1 = _halves(g)
    prog = repro.compile(net, split_xcf(g, d0, d1), block=BLOCK)
    stream = drain_source(g)
    prog.run()
    ref = list(got)
    with prog.serve(batching=True) as server:
        sessions = [server.open_session() for _ in range(2)]
        for s in sessions:
            s.submit(stream)
            s.close()
        assert server.drain(timeout=120)
        for s in sessions:
            assert s.output("sink") == ref  # bitwise, via the serve path


# ---------------------------------------------------------------------------
# Satellite: exhaustive small-N placement sweep
# ---------------------------------------------------------------------------

SWEEP = [
    ("TopFilter", dict(n=64)),
    ("FIR32", dict(taps=4, n=64)),
    ("Bitonic8", dict(n_vectors=4)),
    ("IDCT8", dict(n_blocks=4)),
    ("ZigZag", dict(n_blocks=2)),
]


@pytest.mark.parametrize("name,kw", SWEEP, ids=[s[0] for s in SWEEP])
def test_placement_sweep_exhaustive(name, kw):
    """Every legal 2-partition device split of each Table-I network (plus
    ZigZag) golden-checks against the host reference."""
    builder = NETWORKS[name]
    net, got = builder(**kw)
    g = net.graph()
    splits = legal_two_splits(g)
    if not splits:  # TopFilter: one device-eligible actor, nothing to split
        assert len(_eligible(g)) < 2
        pytest.skip(f"{name}: fewer than two device-eligible actors")
    repro.compile(net, backend="host").run()
    host = list(got)
    assert host
    for d0, d1 in splits:
        prog = repro.compile(net, split_xcf(g, d0, d1), block=64)
        prog.run()
        out = list(got)
        assert len(out) == len(host), (d0, d1)
        np.testing.assert_allclose(
            out, host, rtol=1e-5, atol=1e-4, err_msg=f"split {d0} | {d1}"
        )


# ---------------------------------------------------------------------------
# Satellite: multi-lane serving equivalence + single<->multi hot-swap
# ---------------------------------------------------------------------------


def test_serving_multi_lane_staggered_equals_sequential():
    """B staggered sessions over a 2-device-partition XCF, bitwise equal to
    B sequential ``Program.run()`` streams."""
    sizes = [4, 6, 5]
    refs, streams = [], []
    for sz in sizes:
        net, got = NETWORKS["ZigZag"](sz)
        prog = repro.compile(net, backend="device", block=BLOCK)
        streams.append(drain_source(prog.graph))
        prog.run()
        refs.append(list(got))

    net, _ = NETWORKS["ZigZag"](sizes[0])
    g = net.graph()
    prog = repro.compile(net, split_xcf(g, *_halves(g)), block=BLOCK)
    with prog.serve(batching=True) as server:
        sessions = [server.open_session() for _ in sizes]
        offsets = [0] * len(sessions)
        chunks = [96, 160, 64]
        while any(o < len(st) for o, st in zip(offsets, streams)):
            for i, s in enumerate(sessions):
                if offsets[i] < len(streams[i]):
                    c = streams[i][offsets[i]:offsets[i] + chunks[i % 3]]
                    s.submit(c)
                    offsets[i] += len(c)
        for s in sessions:
            s.close()
        assert server.drain(timeout=120)
        for s, ref in zip(sessions, refs):
            assert s.output() == ref  # bitwise
        t = server.telemetry.lifetime()
    assert t.device_lanes > t.device_dispatches  # batching actually shared


def test_serving_hot_swap_between_single_and_multi_partition():
    """A session stream survives a mid-stream hot-swap from a
    single-partition XCF to a 2-partition one and back, bit-identically."""
    net, got = NETWORKS["ZigZag"](9)
    g = net.graph()
    prog = repro.compile(net, backend="device", block=BLOCK)
    stream = drain_source(g)
    prog.run()
    ref = list(got)

    single_xcf = prog.xcf
    multi_xcf = split_xcf(g, *_halves(g))
    third = len(stream) // 3

    def wait_swaps(server, n, timeout=60.0):
        import time

        deadline = time.perf_counter() + timeout
        while len(server.telemetry.swap_log) < n:
            assert time.perf_counter() < deadline, "swap never landed"
            time.sleep(0.005)

    with prog.serve(batching=True) as server:
        s = server.open_session()
        s.submit(stream[:third])
        server.request_repartition(multi_xcf)  # single -> multi
        wait_swaps(server, 1)  # requests coalesce; let the first land
        s.submit(stream[third:2 * third])
        server.request_repartition(single_xcf)  # multi -> single
        wait_swaps(server, 2)
        s.submit(stream[2 * third:])
        s.close()
        assert server.drain(timeout=120)
        assert s.output() == ref  # no token lost, dropped, or reordered
        assert server.program.xcf is single_xcf
        assert len(server.telemetry.swap_log) == 2


# ---------------------------------------------------------------------------
# Satellite fix: runtime_from_xcf rejects unknown code generators
# ---------------------------------------------------------------------------


def test_runtime_from_xcf_rejects_unknown_code_generator():
    net, _ = NETWORKS["TopFilter"](64)
    g = net.graph()
    xcf = make_xcf(g.name, {a: "p0" for a in g.actors})
    xcf.partitions["p0"].code_generator = "systemc"
    with pytest.raises(GraphError) as e:
        runtime_from_xcf(g, xcf)
    msg = str(e.value)
    assert "'p0'" in msg and "systemc" in msg
    assert "hw" in msg and "sw" in msg  # the known generator set, by name


# ---------------------------------------------------------------------------
# DSE: explore() emits multi-partition design points
# ---------------------------------------------------------------------------


def test_explore_emits_multi_partition_points():
    net, _ = NETWORKS["IDCT8"](16)
    prog = repro.compile(net, block=128)
    prof = prog.profile(block=128, include_links=False)
    points = prog.explore(
        prof, thread_counts=(1,), accel_options=(0, 1, 2), accel_capacity=2
    )
    by_accels = {p.n_accels: p for p in points}
    assert set(by_accels) == {0, 1, 2}
    two = by_accels[2]
    used = {
        pid for pid in two.solution.assignment.values()
        if pid in two.accel_ids
    }
    # capacity=2 cannot fit all three device actors in one partition
    assert len(used) == 2
    hw_parts = [
        p for p in two.xcf.partitions.values() if p.code_generator == "hw"
    ]
    assert len(hw_parts) == 2
    for spec in hw_parts:
        assert 0 < len(spec.instances) <= 2
    # the emitted XCF compiles and runs through the ordinary pipeline
    placed = prog.repartition(xcf=two.xcf)
    assert len(placed.hw_partitions) == 2
    r = placed.run()
    assert r.fires > 0 and r.plink_launches > 0
