"""Paper §V-C: multi-objective partitioning — minimize T + α·R where R charges
device resource use.  Sweeping α traces the performance/resource Pareto front."""


from repro.core.milp import solve_exact

from test_milp import chain_graph, make_profile


def test_alpha_sweep_traces_pareto_front():
    g = chain_graph(5)
    prof = make_profile(g, sw=[1.0], hw=[0.05])
    front = []
    for alpha in (0.0, 0.02, 0.1, 1.0, 10.0):
        sol = solve_exact(
            g, prof, ["t0", "t1", "accel"], alpha=alpha,
            resource=lambda a: 1.0,
        )
        n_hw = sum(1 for p in sol.assignment.values() if p == "accel")
        t = sol.detail["T_exec"]
        front.append((alpha, n_hw, t))
    alphas, n_hws, times = zip(*front)
    # resource use decreases monotonically as it gets more expensive
    assert list(n_hws) == sorted(n_hws, reverse=True)
    # and execution time correspondingly rises (or stays flat)
    assert list(times) == sorted(times)
    # extremes: free hardware -> use it; prohibitive -> software-only
    assert n_hws[0] > 0
    assert n_hws[-1] == 0


def test_resource_weights_steer_placement():
    """Per-actor resource weights (e.g. LUT estimates): an expensive actor is
    evicted from the device before a cheap one."""
    g = chain_graph(3)
    prof = make_profile(g, sw=[1.0], hw=[0.05])
    actors = sorted(a for a in g.actors if g.actors[a].device_ok)
    big = actors[0]

    def resource(a):
        return 100.0 if a == big else 1.0

    sol = solve_exact(g, prof, ["t0", "accel"], alpha=0.05, resource=resource)
    assert sol.assignment[big] != "accel"
    assert any(
        p == "accel" for a, p in sol.assignment.items() if a != big
    )
