"""streamtrace: recorder/metrics units, Chrome-trace golden structure,
tracing-is-free bitwise equivalence, trace-replay profile equivalence, and
the ServerTelemetry window-atomicity regression."""

import json
import threading

import pytest

import repro
from repro.apps.streams import NETWORKS
from repro.core.profiler import profile_from_telemetry, profile_from_trace
from repro.core.partitioner import best_point, explore
from repro.observability import (
    Histogram,
    MetricsRegistry,
    TraceRecorder,
    activate,
    chrome_trace,
    current,
    phase_totals,
    snapshot_from_trace,
    validate_chrome_trace,
)
from repro.serve_stream.telemetry import ServerTelemetry

SIZES = {"TopFilter": 1200, "FIR32": 600, "Bitonic8": 48, "IDCT8": 48,
         "ZigZag": 12}


def _build(name, size):
    builder = NETWORKS[name]
    return builder(n=size) if name == "FIR32" else builder(size)


# ---------------------------------------------------------------------------
# Recorder units
# ---------------------------------------------------------------------------


def test_recorder_events_merge_and_sort():
    rec = TraceRecorder()
    rec.complete("a", "later", "cat", rec.t0_ns + 100, 10)
    rec.complete("b", "earlier", "cat", rec.t0_ns + 5, 10)
    rec.instant("a", "inst", "cat")
    evs = rec.events()
    assert [e[2] for e in evs[:2]] == ["earlier", "later"]
    assert rec.total_events() == 3
    assert rec.drops() == {}


def test_recorder_ring_drops_oldest_and_accounts():
    rec = TraceRecorder(capacity_per_thread=64)
    for i in range(100):
        rec.complete("t", f"e{i}", "cat", rec.t0_ns + i, 1)
    assert rec.total_events() == 64
    (dropped,) = rec.drops().values()
    assert dropped == 36
    names = [e[2] for e in rec.events()]
    assert names[0] == "e36" and names[-1] == "e99"  # oldest overwritten
    # the export surfaces the drop accounting instead of hiding it
    payload = chrome_trace(rec)
    assert sum(payload["otherData"]["dropped"].values()) == 36


def test_activate_restores_previous_recorder():
    assert current() is None
    r1, r2 = TraceRecorder(), TraceRecorder()
    with activate(r1):
        assert current() is r1
        with activate(r2):
            assert current() is r2
        assert current() is r1
        with activate(None):  # no-op context
            assert current() is r1
    assert current() is None


def test_validate_chrome_trace_catches_malformed():
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": 1.0},  # no dur
        {"name": "c", "ph": "C", "pid": 1, "tid": 1, "ts": 1.0,
         "args": {}},                                             # no value
        {"name": "z", "ph": "Z", "pid": 1, "tid": 1, "ts": 1.0},  # bad ph
    ]}
    errs = validate_chrome_trace(bad)
    assert len(errs) >= 3
    assert validate_chrome_trace({"traceEvents": []}) == []


# ---------------------------------------------------------------------------
# Metrics units
# ---------------------------------------------------------------------------


def test_histogram_percentiles_and_summary():
    h = Histogram("lat", "test")
    h.observe(3.0)
    assert h.percentile(50) == pytest.approx(3.0)  # clamped to the sample
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 101 and s["min"] == 1.0 and s["max"] == 100.0
    # log-bucketed (growth 2): percentile error bounded by the bucket ratio
    assert 25.0 <= s["p50"] <= 100.0
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_histogram_prometheus_exposition():
    h = Histogram("lat_s", "latency", bounds=[0.1, 1.0, 10.0])
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    lines = h.expose()
    assert "# TYPE lat_s histogram" in lines
    assert 'lat_s_bucket{le="0.1"} 1' in lines
    assert 'lat_s_bucket{le="+Inf"} 4' in lines
    assert "lat_s_count 4" in lines


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("hits", "hits")
    c.inc(3)
    assert reg.counter("hits") is c and c.value == 3
    reg.gauge("depth").set(7)
    reg.histogram("lat").observe(0.5)
    with pytest.raises(TypeError):
        reg.counter("lat")
    text = reg.expose_text()
    assert "# TYPE hits counter" in text
    assert "# TYPE depth gauge" in text
    assert "lat_count 1" in text


# ---------------------------------------------------------------------------
# Golden Chrome-trace structure (FIR32 device run)
# ---------------------------------------------------------------------------


def test_traced_device_run_golden_structure(tmp_path):
    net, got = _build("FIR32", 600)
    prog = repro.compile(net, backend="device", block=64)
    path = tmp_path / "fir32.trace.json"
    rep = prog.run(trace=str(path))
    assert rep.trace is not None
    # the written artifact is valid JSON and identical to the report payload
    assert json.loads(path.read_text()) == rep.trace
    errs = validate_chrome_trace(
        rep.trace,
        require_cats=["actor", "plink", "run", "channel"],
        require_tracks=["lane:", "runtime", "channels"],
    )
    assert errs == []
    plink_names = {
        ev["name"] for ev in rep.trace["traceEvents"]
        if ev.get("cat") == "plink"
    }
    assert plink_names == {"stage", "dispatch", "sync", "retire"}
    assert len(list(got)) == 600


def test_phase_totals_match_plink_stats():
    net, _got = _build("FIR32", 600)
    rec = TraceRecorder()
    with activate(rec):
        prog = repro.compile(net, backend="device", block=64)
        rt = prog._build_runtime()
        rt.run_threads()
    lanes = phase_totals(rec)
    for pl in rt.plinks.values():
        d = lanes[f"lane:{pl.name}"]
        assert d["launches"] == pl.stats.launches
        for f in ("stage", "dispatch", "sync", "retire"):
            live = getattr(pl.stats, f + "_ns")
            # ns -> µs -> ns float round-trip: sub-ns slack per span
            assert d[f + "_ns"] == pytest.approx(live, abs=2.0)


# ---------------------------------------------------------------------------
# Tracing is observation only: bitwise-identical outputs, all five networks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_tracing_does_not_change_output(name):
    size = SIZES[name]
    net, got = _build(name, size)
    repro.compile(net, backend="device", block=64).run()
    plain = list(got)
    net, got = _build(name, size)
    rep = repro.compile(net, backend="device", block=64).run(trace=True)
    assert list(got) == plain
    assert validate_chrome_trace(rep.trace) == []


# ---------------------------------------------------------------------------
# Serve: lifecycle events + exact trace <-> telemetry replay -> same DSE
# ---------------------------------------------------------------------------


def _serve_fir32_traced(n=600, block=64):
    net, _ = _build("FIR32", n)
    prog = repro.compile(net, backend="device", block=block)
    with prog.serve(trace=True) as server:
        s = server.open_session()
        for i in range(0, n, 100):
            s.submit([float(v) for v in range(i, i + 100)])
        s.close()
        assert s.join(60)
        payload = server.trace()
        life = server.telemetry.lifetime()
        mtext = server.metrics_text()
    return prog, payload, life, mtext


def test_traced_serve_session_events_and_metrics():
    _prog, payload, life, mtext = _serve_fir32_traced()
    errs = validate_chrome_trace(
        payload,
        require_cats=["session", "device", "channel"],
        require_tracks=["session:0", "batch:"],
    )
    assert errs == []
    session_names = [
        ev["name"] for ev in payload["traceEvents"]
        if ev.get("cat") == "session"
    ]
    assert session_names[0] == "session_open"
    assert "submit" in session_names and "deliver" in session_names
    assert session_names[-1] == "session_close"
    # SLO histograms observed and exposed in Prometheus text format
    assert "serve_ttfo_seconds_count 1" in mtext
    assert "serve_interblock_seconds" in mtext
    assert life.tokens_delivered > 0


def test_snapshot_from_trace_equals_lifetime_telemetry():
    _prog, payload, life, _ = _serve_fir32_traced()
    snap = snapshot_from_trace(payload)
    for f in ("actor_fires", "actor_time_ns", "channel_tokens",
              "device_dispatches", "device_lanes", "device_time_ns",
              "device_tokens_in", "device_tokens_out", "sessions_opened",
              "sessions_closed", "chunks_submitted", "tokens_submitted",
              "tokens_delivered", "swaps"):
        assert getattr(snap, f) == getattr(life, f), f


def test_profile_from_trace_drives_same_milp_decision():
    prog, payload, life, _ = _serve_fir32_traced()
    graph = prog.graph
    base = prog.profile(include_links=False)
    live_prof = profile_from_telemetry(graph, life, base=base)
    trace_prof = profile_from_trace(
        graph, payload, base=base, seconds=life.seconds
    )
    assert trace_prof.exec_sw == live_prof.exec_sw
    assert trace_prof.exec_sw_fused == live_prof.exec_sw_fused
    assert trace_prof.exec_hw == live_prof.exec_hw
    assert trace_prof.tokens == live_prof.tokens
    kw = dict(thread_counts=(1, 2), accel_options=(False, True))
    live_best = best_point(explore(graph, live_prof, **kw))
    trace_best = best_point(explore(graph, trace_prof, **kw))
    assert trace_best.xcf.assignment() == live_best.xcf.assignment()


# ---------------------------------------------------------------------------
# ServerTelemetry window atomicity (regression)
# ---------------------------------------------------------------------------


def test_submitted_counters_window_atomic():
    """A snapshot racing client-side submissions must never split one
    submission's chunk and token counts across two windows.  Before
    ``submitted()``, ``notify_work`` made two separate ``count()`` calls; a
    snapshot between them violated tokens == K * chunks per window."""
    t = ServerTelemetry()
    K = 7
    N = 4000
    stop = threading.Event()
    windows = []

    def snapper():
        while not stop.is_set():
            windows.append(t.snapshot())
        windows.append(t.snapshot())

    threads = [threading.Thread(target=snapper) for _ in range(2)]
    for th in threads:
        th.start()
    workers = [
        threading.Thread(
            target=lambda: [t.submitted(1, K) for _ in range(N)]
        )
        for _ in range(3)
    ]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    stop.set()
    for th in threads:
        th.join()
    windows.append(t.snapshot())
    for snap in windows:
        assert snap.tokens_submitted == K * snap.chunks_submitted
    assert sum(s.chunks_submitted for s in windows) == 3 * N
    life = t.lifetime()
    assert life.chunks_submitted == 3 * N
    assert life.tokens_submitted == 3 * N * K
