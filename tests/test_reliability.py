"""Fault-tolerant StreamServe: deterministic chaos injection, per-session
checkpoint/restore (kill-and-recover bit-identity on every Table-I network),
bounded launch retry, graceful degradation to the all-host placement,
per-session blast-radius isolation, and the checkpoint-layer hardening
(AsyncCheckpointer error surfacing, torn-write invisibility)."""

import time

import numpy as np
import pytest

import repro
from repro import checkpoint as ckpt
from repro.apps.streams import NETWORKS
from repro.checkpoint import AsyncCheckpointer
from repro.runtime import chaos
from repro.serve_stream import ServeError, StreamServer

BLOCK = 256

SIZES = {
    "TopFilter": 1200,
    "FIR32": 600,
    "Bitonic8": 48,
    "IDCT8": 48,
    "ZigZag": 9,
}
EGRESS = {"FIR32": "sink"}  # FIR also has the x-forward xsink


def drain_source(graph, name="source"):
    actor = graph.actors[name]
    action = actor.actions[0]
    state = dict(actor.initial_state)
    out = []
    while action.guard is None or action.guard(state, {}):
        state, produced = action.fire(state, {})
        vals = produced.get(actor.outputs[0].name, [])
        if not vals:
            break
        out.extend(vals)
    return out


def _build(name, size):
    builder = NETWORKS[name]
    return builder(size) if name != "FIR32" else builder(n=size)


def _reference(name, size):
    net, got = _build(name, size)
    prog = repro.compile(net, backend="device", block=BLOCK)
    stream = drain_source(prog.graph)
    prog.run()
    return stream, list(got)


def _compiled(name, size, **kw):
    net, _ = _build(name, size)
    return repro.compile(net, backend="device", block=BLOCK, **kw)


# ---------------------------------------------------------------------------
# chaos: the deterministic injection layer itself
# ---------------------------------------------------------------------------


def test_chaos_rule_parse_roundtrip():
    c = chaos.parse("launch:*|at=2,5;actor:f@s0|after=3;plink:*|p=0.25", seed=9)
    assert [r.spec() for r in c.rules] == [
        "launch:*|at=2,5", "actor:f@s0|after=3", "plink:*|p=0.25",
    ]
    assert c.seed == 9
    # coerce() accepts a controller, a spec string, a rule list, and None
    assert chaos.coerce(c) is c
    assert chaos.coerce(None) is None
    assert chaos.coerce("launch:*|at=1").rules[0].at == (1,)
    assert chaos.coerce([chaos.FaultRule("ckpt:*", after=2)]).rules[0].after == 2
    with pytest.raises(ValueError):
        chaos.parse("launch:*|frobnicate=1")


def test_chaos_occurrence_triggers_are_deterministic():
    """p-rules are a pure function of (seed, site, n): two controllers with
    the same seed inject at identical occurrence indices, a different seed
    gives a different (but still reproducible) schedule."""

    def schedule(seed):
        c = chaos.Chaos([chaos.FaultRule("x:*", p=0.3)], seed=seed)
        hits = []
        for i in range(200):
            try:
                c.poke("x:a")
            except chaos.InjectedFault:
                hits.append(i)
        return hits

    a, b = schedule(7), schedule(7)
    assert a == b and len(a) > 10
    assert schedule(8) != a


def test_chaos_at_after_and_delay():
    c = chaos.Chaos([
        chaos.FaultRule("launch:p0", at=(2,)),
        chaos.FaultRule("actor:*", after=3),
        chaos.FaultRule("plink:*", at=(1,), delay_s=0.05),
    ])
    c.poke("launch:p0")
    with pytest.raises(chaos.InjectedLaunchFailure):
        c.poke("launch:p0")
    c.poke("launch:p0")  # at= is exact, not persistent
    c.poke("actor:f@s0")
    c.poke("actor:f@s0")
    for _ in range(3):  # after= is a dead lane: every occurrence >= 3 fails
        with pytest.raises(chaos.InjectedActorFailure):
            c.poke("actor:f@s0")
    t0 = time.perf_counter()
    c.poke("plink:dev0")  # delay rules stall instead of raising
    assert time.perf_counter() - t0 >= 0.05
    assert c.occurrences("launch:p0") == 3
    assert [h[0] for h in c.hits] == [
        "launch:p0", "actor:f@s0", "actor:f@s0", "actor:f@s0", "plink:dev0",
    ]


def test_scheduler_mode_actor_site_fires():
    """Program.run() (not serve): the thread scheduler's per-partition
    actor site injects and the fault propagates as a run error."""
    net, _ = _build("TopFilter", 600)
    prog = repro.compile(net, backend="host", block=BLOCK)
    rule = chaos.FaultRule("actor:filter@*", at=(1,))
    with chaos.activate(chaos.Chaos([rule])):
        with pytest.raises(chaos.InjectedActorFailure):
            prog.run()


def test_plink_lane_site_fires_before_staging():
    """An injected lane death in scheduler mode surfaces as a run error —
    and because the site fires before ``_stage_inputs``, no host FIFO was
    drained into the launch that never happened."""
    net, _ = _build("TopFilter", 600)
    prog = repro.compile(net, backend="device", block=BLOCK)
    with chaos.activate(chaos.Chaos([chaos.FaultRule("plink:*", at=(1,))])):
        with pytest.raises(chaos.InjectedLaneDeath):
            prog.run()


def test_chaos_env_activation(monkeypatch):
    monkeypatch.setenv("REPRO_CHAOS", "launch:*|at=1")
    monkeypatch.setenv("CHAOS_SEED", "42")
    c = chaos.from_env()
    assert c is not None and c.seed == 42
    assert chaos.current() is None
    with chaos.activate(c):
        assert chaos.current() is c
        with pytest.raises(chaos.InjectedLaunchFailure):
            chaos.poke("launch:dev0")
    assert chaos.current() is None
    chaos.poke("launch:dev0")  # no controller installed: free


# ---------------------------------------------------------------------------
# tentpole 1: kill-and-recover bit-identity on every Table-I network
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_kill_and_recover_bitwise(name, tmp_path):
    size = SIZES[name]
    stream, ref = _reference(name, size)
    half = len(stream) // 2

    server = _compiled(name, size).serve(start=True)
    s = server.open_session()
    if half:
        s.submit(stream[:half])
    if half >= 2 * BLOCK:  # big streams: checkpoint after real delivery
        deadline = time.time() + 60
        while s.first_delivery_ns is None and time.time() < deadline:
            time.sleep(0.005)
        assert s.first_delivery_ns is not None
    path = server.checkpoint(tmp_path)
    assert path.exists()
    server.kill()  # no shutdown flush — simulates an engine crash

    server2 = StreamServer.recover(_compiled(name, size), tmp_path, start=True)
    try:
        rep = server2.recovery
        assert rep is not None and not rep.sessions[0].finished
        assert rep.sessions[0].replay_bound >= 0
        s2 = server2.session(0)
        s2.submit(stream[half:])
        s2.close()
        assert server2.drain(timeout=120)
        assert s2.output(EGRESS.get(name)) == ref  # bitwise
    finally:
        server2.stop()


def test_recover_reports_replay_bound_and_restored_delivery(tmp_path):
    stream, ref = _reference("TopFilter", 1200)
    server = _compiled("TopFilter", 1200).serve(start=True)
    s = server.open_session()
    s.submit(stream[:600])
    deadline = time.time() + 60
    while s.first_delivery_ns is None and time.time() < deadline:
        time.sleep(0.005)
    server.checkpoint(tmp_path)
    server.kill()

    server2 = StreamServer.recover(_compiled("TopFilter", 1200), tmp_path)
    rep = server2.recovery
    sr = rep.sessions[0]
    assert sr.delivered_restored > 0          # results survived the kill
    assert sr.replay_bound == sr.queued_tokens + sr.in_pipeline_tokens
    assert rep.replayed_tokens_bound == sr.replay_bound
    assert rep.step == 1
    # the restored session must not re-observe TTFO for replayed blocks
    s2 = server2.session(0)
    assert s2.first_delivery_ns is not None
    server2.start()
    try:
        s2.submit(stream[600:])
        s2.close()
        assert server2.drain(timeout=120)
        assert s2.output() == ref
    finally:
        server2.stop()


def test_recover_rejects_wrong_network_and_missing_checkpoint(tmp_path):
    with pytest.raises(ServeError, match="no complete checkpoint"):
        StreamServer.recover(_compiled("IDCT8", 48), tmp_path)
    server = _compiled("IDCT8", 48).serve()
    server.checkpoint(tmp_path)  # engine not started: inline snapshot
    with pytest.raises(ServeError, match="network"):
        StreamServer.recover(_compiled("ZigZag", 9), tmp_path)


def test_recover_drr_state_dropped_for_finished_sessions(tmp_path):
    """A session that finished before the checkpoint must not leave stale
    sids in the restored deficit-round-robin state, and its buffered output
    must still be readable after recovery."""
    stream, ref = _reference("IDCT8", 48)
    server = _compiled("IDCT8", 48).serve(start=True)
    done = server.open_session()
    done.submit(stream)
    done.close()
    assert done.join(timeout=60)
    live = server.open_session()
    live.submit(stream[: len(stream) // 2])
    server.checkpoint(tmp_path)
    server.kill()

    server2 = StreamServer.recover(_compiled("IDCT8", 48), tmp_path)
    assert server2.recovery.sessions[done.sid].finished
    sched_sids = set(server2._sched._last_round) | set(server2._sched._served)
    assert done.sid not in sched_sids  # no stale DRR entries
    d2, l2 = server2.session(done.sid), server2.session(live.sid)
    assert d2.output() == ref  # finished session restored verbatim
    server2.start()
    try:
        l2.submit(stream[len(stream) // 2:])
        l2.close()
        assert server2.drain(timeout=120)
        assert l2.output() == ref
        assert server2._next_sid > live.sid  # fresh sids never collide
    finally:
        server2.stop()


def test_periodic_checkpointing_recovers_from_last_complete_step(tmp_path):
    """checkpoint_every_s: the engine snapshots on its own clock; after a
    kill, recovery comes from whatever step completed last."""
    stream, ref = _reference("TopFilter", 1200)
    server = _compiled("TopFilter", 1200).serve(
        start=True, checkpoint_dir=tmp_path, checkpoint_every_s=0.05,
    )
    s = server.open_session()
    s.submit(stream[:600])
    deadline = time.time() + 60
    while ckpt.latest_step(tmp_path) is None and time.time() < deadline:
        time.sleep(0.01)
    assert ckpt.latest_step(tmp_path) is not None
    server.kill()

    server2 = StreamServer.recover(_compiled("TopFilter", 1200), tmp_path,
                                   start=True)
    try:
        s2 = server2.session(0)
        s2.submit(stream[600:])
        s2.close()
        assert server2.drain(timeout=120)
        assert s2.output() == ref
    finally:
        server2.stop()


# ---------------------------------------------------------------------------
# tentpole 2+3: injected faults — retry, degradation, blast radius
# ---------------------------------------------------------------------------


def test_transient_launch_fault_retried_bitwise():
    """One injected launch failure: the bounded retry replays the identical
    round (the chaos site fires before staging, so no tokens were drained)
    and the stream completes bit-identically with zero degradation."""
    stream, ref = _reference("TopFilter", 1200)
    prog = _compiled("TopFilter", 1200)
    with prog.serve(chaos="launch:*|at=2") as server:
        s = server.open_session()
        s.submit(stream)
        s.close()
        assert server.drain(timeout=120)
        assert s.output() == ref  # bitwise despite the mid-stream fault
        assert server.chaos.hits  # the fault actually fired
        assert server._c_faults.value >= 1
        assert server._c_recoveries.value >= 1
        assert server._g_degraded.value == 0
        assert not server._quarantined
        text = server.metrics_text()
        assert "serve_faults_total" in text
        assert "serve_recoveries_total" in text


def test_persistent_launch_failure_degrades_to_host():
    """Every launch fails: the partition exhausts its retry budget, is
    quarantined, and sessions hot-swap to the all-host placement — outputs
    stay bit-identical (host == hetero is the conformance invariant)."""
    stream, ref = _reference("TopFilter", 1200)
    prog = _compiled("TopFilter", 1200)
    with prog.serve(chaos="launch:*|after=1", launch_retries=2,
                    retry_base_s=0.001) as server:
        s = server.open_session()
        s.submit(stream)
        s.close()
        assert server.drain(timeout=120)
        assert s.output() == ref
        assert server._quarantined  # the lane is out of rotation
        assert server._g_degraded.value == 1
        assert server.program.hw_partition is None  # now all-host
        assert server.telemetry.lifetime().swaps == 1


def test_lane_death_mid_service_degrades_and_completes():
    """The PLink-site variant: the lane dies after some healthy launches
    (tokens already flowed through the device), then every later launch
    fails — degradation must carry the in-flight residue to the host
    placement without loss or reorder."""
    stream, ref = _reference("TopFilter", 2000)
    prog = _compiled("TopFilter", 2000)
    with prog.serve(chaos="launch:*|after=2", launch_retries=1,
                    retry_base_s=0.001) as server:
        s = server.open_session()
        s.submit(stream)
        s.close()
        assert server.drain(timeout=120)
        out = s.output()
        assert len(out) == len(ref)
        assert out == ref
        assert server._g_degraded.value == 1


def test_actor_fault_isolated_to_one_session():
    """One session's actor raising must fail THAT session (traceback
    captured, output() raises) while the engine keeps serving the others —
    the blast-radius fix for the engine-wide ``except BaseException``."""
    net, got = _build("TopFilter", 1200)
    prog = repro.compile(net, backend="host", block=BLOCK)
    stream = drain_source(prog.graph)
    prog.run()
    ref = list(got)
    net2, _ = _build("TopFilter", 1200)
    prog2 = repro.compile(net2, backend="host", block=BLOCK)
    with prog2.serve(chaos="actor:*@s0|at=1") as server:
        s0 = server.open_session()
        s1 = server.open_session()
        for s in (s0, s1):
            s.submit(stream)
            s.close()
        assert server.drain(timeout=120)
        assert s1.output() == ref          # the healthy session is untouched
        assert s0.error is not None
        assert "InjectedActorFailure" in s0.error  # traceback captured
        with pytest.raises(ServeError):
            s0.output()
        assert server._c_faults.value >= 1
        # the engine itself survived: a NEW session still completes
        s2 = server.open_session()
        s2.submit(stream)
        s2.close()
        assert server.drain(timeout=120)
        assert s2.output() == ref


def test_chaos_knob_accepts_controller_and_records_hits():
    c = chaos.Chaos([chaos.FaultRule("launch:*", at=(1,))], seed=3)
    stream, ref = _reference("IDCT8", 48)
    prog = _compiled("IDCT8", 48)
    with prog.serve(chaos=c) as server:
        assert server.chaos is c
        s = server.open_session()
        s.submit(stream)
        s.close()
        assert server.drain(timeout=120)
        assert s.output() == ref
    assert [h[0].startswith("launch:") for h in c.hits] == [True]


# ---------------------------------------------------------------------------
# checkpoint-layer hardening (satellites)
# ---------------------------------------------------------------------------


def test_torn_checkpoint_write_is_invisible(tmp_path):
    """A save killed mid-write (leaf or commit) leaves ``latest`` at the
    previous complete step, no torn step dir, and no temp litter."""
    tree = {"a": np.arange(4, dtype=np.float32), "b": np.ones(3)}
    ckpt.save(tmp_path, 1, tree)
    assert ckpt.latest_step(tmp_path) == 1
    for step, rule in ((2, chaos.FaultRule("ckpt:leaf", at=(2,))),
                       (3, chaos.FaultRule("ckpt:commit", at=(1,)))):
        with chaos.activate(chaos.Chaos([rule])):
            with pytest.raises(chaos.InjectedCheckpointFailure):
                ckpt.save(tmp_path, step, tree)
        assert ckpt.latest_step(tmp_path) == 1      # restore point intact
        assert not (tmp_path / f"step_{step}").exists()
        assert not list(tmp_path.glob(".tmp_*"))    # no litter
    restored, _ = ckpt.restore(tmp_path, 1, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), tree["a"])


def test_async_checkpointer_surfaces_background_error(tmp_path):
    """A background save failure is never silent: it re-raises on the next
    save() or wait(), whichever comes first — and is then cleared so the
    checkpointer keeps working."""
    tree = {"x": np.ones(2, dtype=np.float32)}
    acp = AsyncCheckpointer(tmp_path)
    with chaos.activate(chaos.Chaos([chaos.FaultRule("ckpt:commit", at=(1,))])):
        acp.save(1, tree)
        with pytest.raises(chaos.InjectedCheckpointFailure):
            acp.wait()  # surfaces on wait()
    assert ckpt.latest_step(tmp_path) is None  # torn step is invisible
    acp.close()

    acp2 = AsyncCheckpointer(tmp_path)
    with chaos.activate(chaos.Chaos([chaos.FaultRule("ckpt:commit", at=(1,))])):
        acp2.save(1, tree)
        acp2._q.join()  # background failure recorded, not yet surfaced
        with pytest.raises(chaos.InjectedCheckpointFailure):
            acp2.save(2, tree)  # surfaces on the NEXT save()
    acp2.save(2, tree)  # error cleared: the checkpointer still works
    acp2.wait()
    assert ckpt.latest_step(tmp_path) == 2
    acp2.close()


def test_object_dtype_leaves_roundtrip_exact_types(tmp_path):
    """Pickled object leaves (the serve recovery path's token streams) must
    round-trip exact Python/NumPy scalar types — bit-identity depends on
    it (np.float32 + float promotion differs from float64 math)."""
    toks = [np.float32(1.5), float(2.25), np.int32(3), True]
    arr = np.empty(len(toks), dtype=object)
    for i, v in enumerate(toks):
        arr[i] = v
    ckpt.save(tmp_path, 1, {"toks": arr, "num": np.arange(3)})
    flat, _ = ckpt.load_flat(tmp_path, 1)
    back = flat["toks"].tolist()
    assert back == toks
    assert [type(v) for v in back] == [type(v) for v in toks]
    assert flat["num"].dtype == np.arange(3).dtype


def test_simulated_failure_joins_chaos_taxonomy():
    from repro.distributed.fault import SimulatedFailure

    e = SimulatedFailure("boom")
    assert isinstance(e, chaos.InjectedFault)
    assert isinstance(e, RuntimeError)
    assert e.site == "train:step"
