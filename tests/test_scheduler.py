"""Software runtime: quiescence, thread mappings, profiling, hetero runtime."""


import pytest
from helpers import given, settings, st

from repro.runtime.scheduler import HeteroRuntime, HostRuntime

from helpers import make_chain, make_topfilter, topfilter_expected


def test_single_thread():
    g, got = make_topfilter(n=512)
    HostRuntime(g, None).run_single()
    assert got == topfilter_expected(n=512)


@pytest.mark.parametrize(
    "mapping",
    [
        {"source": "a", "filter": "a", "sink": "b"},
        {"source": "a", "filter": "b", "sink": "c"},
        {"source": "a", "filter": "b", "sink": "a"},
    ],
)
def test_threaded_mappings(mapping):
    g, got = make_topfilter(n=512)
    HostRuntime(g, mapping).run_threads()
    assert got == topfilter_expected(n=512)


def test_threaded_repeated_runs_deterministic_result():
    for _ in range(3):
        g, got = make_topfilter(n=256)
        HostRuntime(g, {"source": "a", "filter": "b", "sink": "c"}).run_threads()
        assert got == topfilter_expected(n=256)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_random_chain_mappings(seed):
    import random

    rnd = random.Random(seed)
    g, got = make_chain(n_stages=4, n_tok=64)
    mapping = {a: f"t{rnd.randrange(3)}" for a in g.actors}
    HostRuntime(g, mapping).run_threads()
    assert got == [float(x + 1 + 2 + 3 + 4) for x in range(64)]


def test_profiles_populated():
    g, got = make_topfilter(n=256)
    rt = HostRuntime(g, None)
    rt.run_single()
    assert rt.profiles["filter"].fires == 256
    assert rt.profiles["source"].fires == 256
    assert rt.profiles["sink"].fires == len(topfilter_expected(n=256))
    assert rt.profiles["filter"].time_ns > 0
    toks = rt.channel_tokens()
    assert toks["source.OUT->filter.IN"] == 256


def test_small_fifo_depths_still_correct():
    g, got = make_topfilter(n=300)
    for ch in g.channels:
        object.__setattr__(ch, "depth", 2)
    HostRuntime(g, {"source": "a", "filter": "b", "sink": "c"}).run_threads()
    assert got == topfilter_expected(n=300)


def test_hetero_runtime_matches_host():
    g, got = make_topfilter(n=1024, vectorized=True)
    rt = HeteroRuntime(
        g,
        {"source": "t0", "filter": "accel", "sink": "t0"},
        block=256,
        megastep=False,
    )
    rt.run_threads()
    assert got == topfilter_expected(n=1024)
    assert rt.plink.stats.launches >= 4  # blocks streamed through the device


def test_hetero_runtime_megastep_amortizes_launches():
    g, got = make_topfilter(n=1024, vectorized=True)
    rt = HeteroRuntime(
        g, {"source": "t0", "filter": "accel", "sink": "t0"}, block=256
    )
    rt.run_threads()
    assert got == topfilter_expected(n=1024)
    k = rt.plink.program.megastep_k
    assert k > 1  # default target kicks in
    # one launch moves k blocks: 1024 tokens fit in ceil(1024/(k*256)) launches
    assert rt.plink.stats.launches >= -(-1024 // (k * 256))
    assert rt.plink.stats.tokens_in == 1024
