"""StreamServe: batched multi-session serving bitwise-equal to sequential
``Program.run()``s, admission backpressure, mid-stream XCF hot-swap, online
repartition plumbing, batched kernels, and the satellite fixes (adaptive
scheduler backoff, profiler wall-clock budget, PLink warn-once reset)."""

import time
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.apps.streams import NETWORKS
from repro.core.cost_model import NetworkProfile
from repro.core.profiler import profile_from_telemetry, profile_host
from repro.frontend.program import synthesize_xcf
from repro.kernels.stream_fused import StreamOp, StreamProgram, fused_stream
from repro.runtime.scheduler import AdaptiveBackoff
from repro.serve_stream import (
    AdmissionFull,
    OnlineRepartitioner,
    ServeError,
)
from repro.serve_stream.telemetry import ServerTelemetry

BLOCK = 256


def drain_source(graph, name="source"):
    """The exact token stream the network's source would generate — what a
    serve-mode client submits in its place."""
    actor = graph.actors[name]
    action = actor.actions[0]
    state = dict(actor.initial_state)
    out = []
    while action.guard is None or action.guard(state, {}):
        state, produced = action.fire(state, {})
        vals = produced.get(actor.outputs[0].name, [])
        if not vals:
            break
        out.extend(vals)
    return out


def _build(name, size):
    builder = NETWORKS[name]
    return builder(size) if name != "FIR32" else builder(n=size)


# ---------------------------------------------------------------------------
# Tentpole: N batched sessions == N sequential Program.run()s, bitwise
# ---------------------------------------------------------------------------

SIZES = {  # three per-session workload sizes each (staggered on purpose)
    "TopFilter": [900, 1200, 600],
    "FIR32": [400, 600, 500],
    "Bitonic8": [32, 48, 40],
    "IDCT8": [32, 48, 40],
    "ZigZag": [6, 9, 7],
}
EGRESS = {"FIR32": "sink"}  # FIR also has the x-forward xsink


@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_batched_sessions_bitwise_equal_sequential(name):
    sizes = SIZES[name]
    refs, streams = [], []
    for sz in sizes:
        net, got = _build(name, sz)
        prog = repro.compile(net, backend="device", block=BLOCK)
        streams.append(drain_source(prog.graph))
        prog.run()
        refs.append(list(got))

    net, _ = _build(name, sizes[0])
    prog = repro.compile(net, backend="device", block=BLOCK)
    with prog.serve(batching=True) as server:
        sessions = [server.open_session() for _ in sizes]
        # interleaved, uneven chunks — sessions progress at different speeds
        offsets = [0] * len(sessions)
        chunks = [96, 160, 64]
        while any(o < len(st) for o, st in zip(offsets, streams)):
            for i, s in enumerate(sessions):
                if offsets[i] < len(streams[i]):
                    c = streams[i][offsets[i]:offsets[i] + chunks[i % 3]]
                    s.submit(c)
                    offsets[i] += len(c)
        for s in sessions:
            s.close()
        assert server.drain(timeout=120)
        for s, ref in zip(sessions, refs):
            assert s.output(EGRESS.get(name)) == ref  # bitwise
        t = server.telemetry.lifetime()
    # sessions actually shared launches: more lanes than dispatches
    assert t.device_dispatches >= 1
    assert t.device_lanes > t.device_dispatches
    assert t.tokens_delivered > 0


def test_sequential_dispatch_mode_matches_batched():
    """batching=False (the benchmark baseline) produces the same streams."""
    net, got = _build("IDCT8", 40)
    prog = repro.compile(net, backend="device", block=BLOCK)
    stream = drain_source(prog.graph)
    prog.run()
    ref = list(got)
    for batching in (True, False):
        net2, _ = _build("IDCT8", 40)
        prog2 = repro.compile(net2, backend="device", block=BLOCK)
        with prog2.serve(batching=batching) as server:
            ss = [server.open_session() for _ in range(2)]
            for s in ss:
                s.submit(stream)
                s.close()
            assert server.drain(timeout=60)
            for s in ss:
                assert s.output() == ref


# ---------------------------------------------------------------------------
# Batched kernels / batched device step
# ---------------------------------------------------------------------------


def _demo_program():
    basis = np.linalg.qr(np.random.default_rng(0).normal(size=(8, 8)))[0]
    ops = (
        StreamOp("affine", (0,), 1, (-1.5, 0.25, 3.0)),
        StreamOp("matmul8", (1,), 2, (basis.astype(np.float32),)),
        StreamOp("clip", (2,), 3, (-2.0, 2.0)),
    )
    return StreamProgram(n_inputs=1, n_regs=4, ops=ops, outputs=(3,))


@pytest.mark.parametrize("use", ["ref", "pallas"])
def test_fused_stream_leading_batch_dim_bitident(use):
    """(B, N) wires: one launch, every row bit-identical to its solo run."""
    prog = _demo_program()
    rng = np.random.default_rng(1)
    rows = [rng.normal(size=(64,)).astype(np.float32) for _ in range(5)]
    solo = [
        np.asarray(fused_stream([jnp.asarray(r)], prog, use=use)[0])
        for r in rows
    ]
    (batched,) = fused_stream([jnp.asarray(np.stack(rows))], prog, use=use)
    batched = np.asarray(batched)
    assert batched.shape == (5, 64)
    for i in range(5):
        np.testing.assert_array_equal(batched[i], solo[i])


def test_device_program_batched_step_bitident():
    net, _ = _build("FIR32", 64)
    prog = repro.compile(net, backend="device", block=64)
    dp = prog.device_program()
    rng = np.random.default_rng(0)
    B = 3
    payloads = [
        {
            f"{a}.{p}": (
                jnp.asarray(rng.random(dp.block).astype(np.float32) * 100),
                jnp.ones((dp.block,), bool),
            )
            for (a, p, _dt) in dp.in_ports
        }
        for _ in range(B)
    ]
    solo = [
        dp.step({a: dict(s) for a, s in dp.init_state.items()}, pay)
        for pay in payloads
    ]
    state_b = dp.stack_states([dp.init_state] * B)
    ins_b = {
        k: (
            jnp.stack([p[k][0] for p in payloads]),
            jnp.stack([p[k][1] for p in payloads]),
        )
        for k in payloads[0]
    }
    _, outs_b, idle_b = dp.batched_step(B)(state_b, ins_b)
    for b in range(B):
        _, outs_s, idle_s = solo[b]
        for k in outs_s:
            np.testing.assert_array_equal(
                np.asarray(outs_s[k][0]), np.asarray(outs_b[k][0][b])
            )
            np.testing.assert_array_equal(
                np.asarray(outs_s[k][1]), np.asarray(outs_b[k][1][b])
            )
        assert bool(idle_s) == bool(idle_b[b])


# ---------------------------------------------------------------------------
# Admission backpressure
# ---------------------------------------------------------------------------


def test_admission_backpressure_nonblocking_raises():
    net, _ = _build("TopFilter", 512)
    prog = repro.compile(net, backend="device", block=128)
    server = prog.serve(admission_depth=128)  # engine NOT started
    s = server.open_session()
    s.submit([1.0] * 128, block=False)  # exactly fills the queue
    with pytest.raises(AdmissionFull):
        s.submit([1.0], block=False)
    with pytest.raises(ServeError):  # oversized chunk is rejected up front
        s.submit([1.0] * 129, block=False)
    with pytest.raises(ServeError):  # blocking on a dead engine must not hang
        s.submit([1.0] * 64, timeout=0.2)


def test_admission_backpressure_blocking_completes():
    net, got = _build("TopFilter", 2048)
    prog = repro.compile(net, backend="device", block=128)
    stream = drain_source(prog.graph)
    prog.run()
    ref = list(got)
    net2, _ = _build("TopFilter", 2048)
    prog2 = repro.compile(net2, backend="device", block=128)
    with prog2.serve(admission_depth=256) as server:
        s = server.open_session()
        for i in range(0, len(stream), 200):  # >> queue depth in total
            s.submit(stream[i:i + 200])  # blocks until the engine drains
        s.close()
        assert server.drain(timeout=60)
        assert s.output() == ref
        assert server.telemetry.lifetime().queue_peak <= 256


def test_stalled_stream_fails_loudly():
    """A closed stream with residue below the staging quantum (torn 8-block)
    must fail the session, not hang join() or emit wrong values."""
    net, _ = _build("IDCT8", 8)
    prog = repro.compile(net, backend="device", block=64)
    with prog.serve() as server:
        s = server.open_session()
        s.submit([1.0] * 12)  # 12 % 8 != 0 — the tail can never stage
        s.close()
        assert s.join(timeout=60)
        with pytest.raises(ServeError, match="quantum"):
            s.output()


def test_stalled_stream_fails_loudly_on_host_placement():
    """Same torn tail, but with the 8-consuming actor on a *host* thread
    (no device stage at all): the stall detector must still fire instead of
    hanging join() forever."""
    net, _ = _build("Bitonic8", 8)  # Deal consumes 8 per firing, host-only
    prog = repro.compile(net, backend="host", block=64)
    with prog.serve() as server:
        s = server.open_session()
        s.submit([1.0] * 12)  # 4 tokens can never reach Deal's 8-rate
        s.close()
        assert s.join(timeout=60)
        with pytest.raises(ServeError, match="quantum"):
            s.output()


def test_concurrent_client_threads():
    """Each session driven by its own client thread, submitting chunks
    concurrently against a small queue — exercises the cross-thread
    admission protocol (deferred snapshot/publish) under contention."""
    import threading

    net, got = _build("TopFilter", 4096)
    prog = repro.compile(net, backend="device", block=128)
    stream = drain_source(prog.graph)
    prog.run()
    ref = list(got)
    net2, _ = _build("TopFilter", 4096)
    prog2 = repro.compile(net2, backend="device", block=128)
    with prog2.serve(admission_depth=256) as server:
        sessions = [server.open_session() for _ in range(4)]
        errs = []

        def client(s):
            try:
                for i in range(0, len(stream), 100):
                    s.submit(stream[i:i + 100])  # blocks on backpressure
                s.close()
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [
            threading.Thread(target=client, args=(s,)) for s in sessions
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        assert server.drain(timeout=120)
        for s in sessions:
            assert s.output() == ref


# ---------------------------------------------------------------------------
# Online repartitioning
# ---------------------------------------------------------------------------


def test_hot_swap_mid_stream_no_loss_no_reorder():
    net, got = _build("TopFilter", 2000)
    prog = repro.compile(net, backend="device", block=BLOCK)
    stream = drain_source(prog.graph)
    prog.run()
    ref = list(got)
    net2, _ = _build("TopFilter", 2000)
    prog2 = repro.compile(net2, backend="device", block=BLOCK)
    with prog2.serve() as server:
        ss = [server.open_session() for _ in range(2)]
        for s in ss:
            s.submit(stream[:1000])
        time.sleep(0.05)  # let some tokens flow through the old placement
        server.request_repartition(synthesize_xcf(prog2.graph, "host"))
        for s in ss:
            s.submit(stream[1000:])
            s.close()
        assert server.drain(timeout=120)
        for s in ss:
            out = s.output()
            assert len(out) == len(ref)
            assert out == ref  # nothing dropped, nothing reordered
        t = server.telemetry.lifetime()
        assert t.swaps == 1
        assert server.program.hw_partition is None  # now host-only
        assert server.telemetry.swap_log[0]["to"]["filter"] == "t0"


def test_online_repartitioner_proposes_accel_under_load():
    """Fabricated telemetry showing an expensive host actor + a cheap hw
    profile: the MILP must propose moving it to the accelerator."""
    net, _ = _build("TopFilter", 1024)
    prog = repro.compile(net, backend="host", block=BLOCK)
    base = NetworkProfile()
    base.exec_hw["filter"] = 1e-4  # calibration: filter is cheap on hw
    rep = OnlineRepartitioner(
        interval_s=0.0, min_window_s=0.0, min_gain=0.0, thread_counts=(1,),
        base_profile=base,
    )

    class _FakeServer:
        pass

    fake = _FakeServer()
    fake.program = prog
    fake.telemetry = ServerTelemetry()
    rep.bind(fake)
    t = fake.telemetry
    t.actor_fired("source", 1024, int(1e6))
    t.actor_fired("filter", 1024, int(5e9))  # 5s of host time: the hot spot
    t.actor_fired("sink", 512, int(1e6))
    for key in [("source", "OUT", "filter", "IN"),
                ("filter", "OUT", "sink", "IN")]:
        t.link_moved(key, 1024)
    xcf = rep.propose(t.snapshot())
    assert xcf is not None
    assert xcf.assignment()["filter"] == "accel"
    assert rep.decisions[-1][2] is True


def test_profile_from_telemetry_merges_base():
    net, _ = _build("TopFilter", 64)
    graph = net.graph()
    base = NetworkProfile()
    base.exec_sw["filter"] = 123.0      # stale: live sample must win
    base.exec_sw["sink"] = 7.0          # no live sample: must survive
    base.exec_hw["filter"] = 0.5
    base.tokens[("source", "OUT", "filter", "IN")] = 11
    t = ServerTelemetry()
    t.actor_fired("filter", 10, int(2e9))
    t.link_moved(("source", "OUT", "filter", "IN"), 999)
    prof = profile_from_telemetry(graph, t.snapshot(), base=base)
    assert prof.exec_sw["filter"] == pytest.approx(2.0)
    assert prof.exec_sw["sink"] == 7.0
    assert prof.exec_hw["filter"] == 0.5
    assert prof.tokens[("source", "OUT", "filter", "IN")] == 999


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------


def test_adaptive_backoff_ramps_and_resets():
    b = AdaptiveBackoff(first=1e-4, cap=1e-3, spins=2)
    seq = [b.next_timeout() for _ in range(8)]
    assert seq[0] == 0.0 and seq[1] == 0.0          # spin phase
    assert seq[2] == pytest.approx(1e-4)
    assert all(x <= 1e-3 for x in seq)              # capped
    assert seq[-1] == pytest.approx(1e-3)
    b.reset()
    assert b.next_timeout() == 0.0                  # progress restarts spin


def test_profile_host_wall_clock_budget():
    """A source that never exhausts must not hang profiling."""
    from repro.core.graph import ActorGraph
    from repro.core.actor import sink_actor, source_actor

    g = ActorGraph("endless")
    g.add(source_actor("src", lambda st: (st, 1.0)))  # no has_next: forever
    g.add(sink_actor("snk", lambda st, v: st))
    g.connect("src", "snk", depth=64)
    t0 = time.perf_counter()
    prof, _rt = profile_host(g, max_seconds=0.2)
    assert time.perf_counter() - t0 < 5.0
    assert prof.exec_sw["src"] > 0.0


def test_plink_dtype_warning_resettable():
    from repro.runtime.plink import _np_dtype, reset_dtype_warnings

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _np_dtype("no-such-dtype")
        assert len(w) == 1          # first sighting warns
        _np_dtype("no-such-dtype")
        assert len(w) == 1          # warn-once holds
    reset_dtype_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _np_dtype("no-such-dtype")
        assert len(w) == 1          # reset: next offender warns again
