"""Continuous batching correctness: the engine's outputs must equal isolated
per-request greedy decoding, regardless of slot scheduling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.model import lm
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def isolated_generate(cfg, params, prompt, max_new, eos_id=2, max_len=96):
    tokens = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, small = lm.prefill(params, cfg, tokens=tokens)
    big = lm.init_cache(cfg, 1, max_len)

    def splice(b, s):
        if b.ndim >= 3 and s.shape[2] != b.shape[2]:
            pad = [(0, 0)] * s.ndim
            pad[2] = (0, b.shape[2] - s.shape[2])
            s = jnp.pad(s.astype(b.dtype), pad)
        return s.astype(b.dtype)

    cache = jax.tree.map(splice, big, small)
    out = [int(jnp.argmax(logits[0]))]
    pos = tokens.shape[1]
    step = jax.jit(lambda p, c, t, i: lm.decode_step(p, cfg, c, t, i))
    while out[-1] != eos_id and len(out) < max_new and pos < max_len - 1:
        logits, cache = step(
            params, cache, jnp.asarray([out[-1]], jnp.int32), jnp.int32(pos)
        )
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_engine_matches_isolated_generation(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(3, cfg.vocab_size, size=n).astype(np.int32)
        for n in (5, 9, 7, 12, 4)
    ]
    max_news = [6, 10, 4, 8, 5]

    engine = ServingEngine(cfg, params, slots=2, max_len=96)
    reqs = [
        Request(rid=i, prompt=p, max_new=m)
        for i, (p, m) in enumerate(zip(prompts, max_news))
    ]
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert len(done) == len(reqs)

    for r in sorted(done, key=lambda r: r.rid):
        ref = isolated_generate(cfg, params, prompts[r.rid], max_news[r.rid])
        assert r.output == ref, f"request {r.rid}: {r.output} != {ref}"


def test_engine_interleaves_slots(setup):
    """More requests than slots: the engine must still finish them all, and in
    fewer ticks than serial execution would need (continuous batching)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(3, cfg.vocab_size, size=6).astype(np.int32),
            max_new=7,
        )
        for i in range(6)
    ]
    engine = ServingEngine(cfg, params, slots=3, max_len=64)
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert len(done) == 6
    serial_steps = sum(len(r.output) - 1 for r in done)
    assert engine.steps < serial_steps  # slots genuinely shared the ticks
