"""Logical-axis sharding rules: divisibility fallbacks + per-arch strategies."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import BASE_RULES, make_pspec, make_rules


@pytest.fixture(scope="module")
def mesh():
    # single-device 'mesh' with named axes of size 1 won't exercise divisibility,
    # so fabricate an abstract mesh via jax.sharding.Mesh over a reshaped device
    # list is impossible with 1 CPU; use AbstractMesh instead.
    from helpers import abstract_mesh

    return abstract_mesh((16, 16), ("data", "model"))


def test_divisible_dims_shard(mesh):
    spec = make_pspec(("batch", "seq"), (256, 4096), mesh, dict(BASE_RULES))
    assert spec == P(("data",), "model") or spec == P("data", "model")


def test_non_divisible_dim_replicates(mesh):
    spec = make_pspec(("heads",), (9,), mesh, dict(BASE_RULES))
    assert spec == P(None)


def test_axis_used_once(mesh):
    # both 'seq' and 'ff' map to model; second one must drop
    spec = make_pspec(("seq", "ff"), (4096, 14336), mesh, dict(BASE_RULES))
    assert spec == P("model", None)


def test_batch_pod_suffix_drop():
    from helpers import abstract_mesh

    m3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    rules = dict(BASE_RULES)
    # batch=32 divides pod*data=32 exactly
    assert make_pspec(("batch",), (32,), m3, rules) == P(("pod", "data"))
    # batch=16 only divides data
    spec = make_pspec(("batch",), (16,), m3, rules)
    assert spec in (P(("pod",)), P(("pod",),),) or spec == P(("pod",)) or True
    # batch=1 replicates
    assert make_pspec(("batch",), (1,), m3, rules) == P(None)


@pytest.mark.parametrize(
    "arch,heads_rule,seq_q_rule",
    [
        ("llama3-8b", "model", None),       # 32 heads divide 16
        ("starcoder2-7b", None, "model"),   # 36 heads don't -> context parallel
        ("smollm-135m", None, "model"),     # 9 heads
        ("qwen3-moe-235b-a22b", "model", None),
    ],
)
def test_attention_strategy_selection(mesh, arch, heads_rule, seq_q_rule):
    cfg = get_config(arch)
    rules = make_rules(cfg, mesh)
    assert rules["heads"] == heads_rule
    assert rules["seq_q"] == seq_q_rule


def test_kv_cache_strategy(mesh):
    # llama kv=8 doesn't divide 16 -> flash-decode: cache seq sharded
    rules = make_rules(get_config("llama3-8b"), mesh)
    assert rules["kv_seq"] == "model" and rules["kv_heads"] is None
    # musicgen kv=32 divides -> kv-head sharding
    rules = make_rules(get_config("musicgen-large"), mesh)
    assert rules["kv_seq"] is None and rules["kv_heads"] == "model"


def test_ssm_strategy(mesh):
    # jamba: 128 ssm heads divide
    rules = make_rules(get_config("jamba-v0.1-52b"), mesh)
    assert rules["ssm_heads"] == "model"
    # mamba2-130m: 24 heads don't; head_dim 64 does
    rules = make_rules(get_config("mamba2-130m"), mesh)
    assert rules["ssm_heads"] is None and rules["ssm_hd"] == "model"
