"""SPMD features that need >1 device: run in a subprocess with 8 fake devices.

Covers: GPipe pipeline == sequential reference; int8 all-reduce over an axis;
sharded train step on a 2x2 mesh runs and matches the single-device loss.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}

    # ---- gpipe vs sequential ----
    from repro.distributed.pipeline import gpipe_apply, stack_stage_params
    mesh = jax.make_mesh((4,), ("stage",))
    key = jax.random.PRNGKey(0)
    per_stage = []
    for i in range(4):
        k1, k2, key = jax.random.split(key, 3)
        per_stage.append({"w": jax.random.normal(k1,(16,16))*0.3,
                          "b": jax.random.normal(k2,(16,))*0.1})
    params = stack_stage_params(per_stage)
    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])
    x = jax.random.normal(key, (6, 5, 16))
    with mesh:
        got = gpipe_apply(stage_fn, params, x, mesh=mesh, axis="stage")
    ref = x
    for p in per_stage:
        ref = jnp.tanh(ref @ p["w"] + p["b"])
    out["gpipe_err"] = float(jnp.max(jnp.abs(got - ref)))

    # ---- int8 all-reduce over an axis ----
    from repro.distributed.compression import all_reduce_int8
    try:
        shard_map = jax.shard_map
        nocheck = {"check_vma": False}
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        nocheck = {"check_rep": False}
    mesh2 = jax.make_mesh((8,), ("d",))
    y = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 128))
    f = shard_map(lambda a: all_reduce_int8(a[0], "d")[None],
                  mesh=mesh2, in_specs=P("d"), out_specs=P("d"),
                  **nocheck)
    with mesh2:
        red = f(y)
    true = jnp.sum(y, 0, keepdims=True)
    rel = float(jnp.linalg.norm(red[0] - true[0]) / jnp.linalg.norm(true[0]))
    out["int8_allreduce_rel"] = rel

    # ---- sharded train step on 2x4 mesh matches 1-device loss ----
    from repro.configs import get_config
    from repro.distributed.sharding import make_rules, shard_ctx
    from repro.launch.steps import make_train_step, params_specs, specs_to_pspecs, batch_specs, opt_specs
    from repro.model import lm
    from repro.optim import OptConfig, init_opt_state
    cfg = get_config("smollm-135m").reduced()
    opt = OptConfig()
    mesh3 = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(cfg, mesh3)
    key = jax.random.PRNGKey(0)
    params = lm.init_model(cfg, key)
    opt_state = init_opt_state(params, opt)
    B, S = 4, 64
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    step = make_train_step(cfg, opt, 1)
    def traced(p, o, b):
        with shard_ctx(mesh3, rules):
            return step(p, o, b)
    with mesh3:
        p_specs, p_log = params_specs(cfg)
        in_sh = specs_to_pspecs(p_specs, p_log, mesh3, rules)
        sharded_params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh3, s), in_sh))
        _, _, m_sharded = jax.jit(traced)(sharded_params, opt_state, batch)
    m_single = step(params, opt_state, batch)[2]
    out["loss_sharded"] = float(m_sharded["loss"])
    out["loss_single"] = float(m_single["loss"])
    print("RESULT " + json.dumps(out))
    """
)


def test_spmd_features():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["gpipe_err"] < 1e-5
    assert out["int8_allreduce_rel"] < 0.02
    assert abs(out["loss_sharded"] - out["loss_single"]) < 0.05
