"""End-to-end behaviour: training improves + resumes, serving terminates on
idleness, the HLO analyzer multiplies loop bodies correctly."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.serve import run_serving
from repro.launch.train import run_training


def test_training_improves_and_survives_failure(tmp_path):
    out = run_training(
        "smollm-135m", steps=30, global_batch=8, seq_len=64,
        ckpt_dir=str(tmp_path), ckpt_every=10, fail_at=15, quiet=True,
    )
    assert out["steps"] == 30
    assert out["restarts"] == 1  # injected failure recovered via checkpoint
    assert out["improved"], (out["loss_first"], out["loss_last"])


def test_training_resume_continues(tmp_path):
    run_training(
        "smollm-135m", steps=10, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path), ckpt_every=5, quiet=True,
    )
    out = run_training(
        "smollm-135m", steps=14, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path), ckpt_every=5, quiet=True,
    )
    # resumed from step 10 -> only 4 fresh losses recorded
    assert len(out["losses"]) == 4


def test_serving_idleness_termination():
    out = run_serving(
        "smollm-135m", batch=2, prompt_len=8, max_new=6, quiet=True
    )
    assert out["output"].shape == (2, 6)
    assert 1 <= out["steps"] <= 6


def test_hlo_analysis_loop_multiplication():
    from repro.launch.hlo_analysis import analyze

    def f(x, w):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    st = analyze(txt)
    want = 7 * 2 * 64 * 64 * 64  # 7 loop iterations of a 64^3 matmul
    assert st.flops == pytest.approx(want, rel=0.05), (st.flops, want)


def test_hlo_analysis_collectives_on_spmd_program():
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze
        mesh = jax.make_mesh((4,), ("d",))
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        sh = NamedSharding(mesh, P("d", None))
        def f(a):
            return jnp.sum(a * 2.0)
        comp = jax.jit(f, in_shardings=sh).lower(x).compile()
        st = analyze(comp.as_text())
        assert st.collective_bytes > 0, "expected an all-reduce"
        print("COLL_OK", st.collective_bytes)
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=300,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COLL_OK" in r.stdout
