"""XCF configuration round-trips and validation."""

import pytest

from repro.core.xcf import XCF, make_xcf

from helpers import make_topfilter


def test_json_roundtrip(tmp_path):
    xcf = make_xcf(
        "example.TopFilter",
        {"source": "accel", "filter": "accel", "sink": "0"},
        meta={"predicted_T": 1.5},
    )
    p = tmp_path / "conf.json"
    xcf.save(p)
    back = XCF.load(p)
    assert back.assignment() == xcf.assignment()
    assert back.meta["predicted_T"] == 1.5
    assert back.partitions["accel"].code_generator == "hw"


def test_xml_matches_paper_listing2_shape():
    xcf = make_xcf("example.TopFilter", {"source": "1", "filter": "1", "sink": "0"})
    xml = xcf.to_xml()
    assert "<configuration>" in xml
    assert '<network id="example.TopFilter"' in xml
    assert "fifo-connection" in xml or "<connections" in xml


def test_validate_rejects_io_actor_on_hw():
    g, _ = make_topfilter()
    xcf = make_xcf(g.name, {"source": "accel", "filter": "accel", "sink": "t0"})
    with pytest.raises(AssertionError, match="cannot be placed on hardware"):
        xcf.validate(g)


def test_validate_requires_total_assignment():
    g, _ = make_topfilter()
    xcf = make_xcf(g.name, {"source": "t0", "filter": "t0"})
    with pytest.raises(AssertionError, match="unassigned"):
        xcf.validate(g)
